"""Symbolic dependence certifier, hint sanitizer and linter (DESIGN.md §12).

Four contracts are pinned here:

  1. **Certifier soundness, differentially** — every verdict the
     certifier emits over random affine programs and the registered
     kernels is checked against brute-force enumeration of the actual
     traced address streams: ``never_conflict`` streams never share an
     address (forced-pass pairs additionally have an all-true §5.6
     NoDependence bit stream), ``min_distance(d)`` conflicts are at
     least ``d`` apart at the shared depth, and symbolically-free ops
     really never collide with a batched store.
  2. **static_prune is behavior-preserving** — cycles and arrays are
     bit-identical with the certifier's forced-pass drops applied, on
     every registered kernel, and across engines × trace modes × modes
     on the kernel whose plan actually shrinks.
  3. **The hint sanitizer and the linter agree** — a contradictory
     ``MonotonicHint`` is caught statically (RPL001) and dynamically
     (``HintViolation`` from both engines and the wave executor, naming
     the op and the first violating instance).
  4. **Lint output is stable** — codes are pinned and the committed
     ``tests/fixtures/lint_all.txt`` run stays reproducible.
"""

import io
import os
from contextlib import redirect_stdout

import numpy as np
import pytest

from repro.analysis import deps, lint
from repro.core import dae as daelib
from repro.core import du as dulib
from repro.core import executor
from repro.core import hazards as hz
from repro.core import loopir as ir
from repro.core import monotonic as mono
from repro.core import programs
from repro.core import schedule as schedlib
from repro.core import simulator

from loopir_strategies import random_affine_program, random_wave_program

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "lint_all.txt")

NONSPEC = sorted(n for n in programs.REGISTRY if not programs.get(n).speculative)


def _small_scale(name: str) -> int:
    return max(8, programs.get(name).default_scale // 8)


# ---------------------------------------------------------------------------
# 1. certifier soundness: verdicts vs brute-force stream enumeration
# ---------------------------------------------------------------------------


def _front_end(prog, arrays, params):
    dres = daelib.decouple(prog)
    infos = mono.analyze_program(prog)
    plan = hz.build_plan(prog, dres, infos, forwarding=False)
    traces = schedlib.trace_program(prog, dres, arrays, params, mode="auto")
    return dres, plan, traces


def _brute_force_check(prog, plan, traces):
    """Verify every non-unknown verdict against the observed streams."""
    facts = deps.stream_facts(prog)
    all_pairs = list(plan.pairs) + [p for p, _r in plan.pruned]
    verdicts = deps.certify_pairs(prog, all_pairs, facts=facts)
    checked = 0
    for pair in all_pairs:
        v = verdicts[(pair.dst, pair.src)]
        dt, st = traces[pair.dst], traces[pair.src]
        if v.kind == deps.NEVER and v.forced_pass:
            # the §5.6 bit must be true at every single evaluation
            bits = dulib.nodependence_bits([pair], traces)[(pair.dst, pair.src)]
            assert bool(np.all(bits)), (pair.dst, pair.src, v.evidence)
            checked += 1
        elif v.kind == deps.NEVER:
            assert not (set(dt.addr.tolist()) & set(st.addr.tolist())), (
                pair.dst, pair.src, v.evidence,
            )
            checked += 1
        elif v.kind == deps.DISTANCE:
            k = pair.shared_depth
            common = set(dt.addr.tolist()) & set(st.addr.tolist())
            for a in common:
                di = dt.sched[dt.addr == a, k - 1]
                sj = st.sched[st.addr == a, k - 1]
                gap = np.abs(di[:, None] - sj[None, :])
                assert int(gap.min()) >= v.distance, (
                    pair.dst, pair.src, a, v.distance, v.evidence,
                )
            checked += 1

    # per-op conflict-freedom certificates (coarsener admission)
    free = deps.symbolically_free_ops(prog, facts=facts)
    store_addrs: dict[str, set] = {}
    for op, _path in prog.mem_ops():
        if op.is_store:
            store_addrs.setdefault(op.array, set()).update(
                traces[op.id].addr.tolist()
            )
    for op, _path in prog.mem_ops():
        if not free.get(op.id):
            continue
        addrs = traces[op.id].addr
        others = set()
        for other, _p in prog.mem_ops():
            if other.id != op.id and other.array == op.array and (
                op.is_store or other.is_store
            ):
                others.update(traces[other.id].addr.tolist())
        assert not (set(addrs.tolist()) & others), op.id
        if op.is_store and len(addrs) > 1:
            assert int(np.diff(addrs).min()) >= 1, op.id
        checked += 1
    return checked


@pytest.mark.parametrize("seed", range(25))
def test_certifier_differential_fuzz(seed):
    rng = np.random.default_rng(seed)
    prog, arrays, params = random_affine_program(rng)
    _dres, plan, traces = _front_end(prog, arrays, params)
    _brute_force_check(prog, plan, traces)


@pytest.mark.parametrize("name", NONSPEC)
def test_certifier_differential_registered(name):
    prog, arrays, params = programs.get(name).make(_small_scale(name))
    _dres, plan, traces = _front_end(prog, arrays, params)
    _brute_force_check(prog, plan, traces)


def test_certifier_finds_forced_pass_on_table1_kernel():
    """≥1 Table-1 kernel has a provably-droppable pair (the ISSUE's
    evidence bar): tanh+spmv's intra-PE RAW on the gather array."""
    prog, _a, _p = programs.get("tanh+spmv").make(64)
    dres = daelib.decouple(prog)
    infos = mono.analyze_program(prog)
    plan = hz.build_plan(prog, dres, infos, forwarding=False)
    verdicts = deps.certify_pairs(prog, plan.pairs)
    assert any(v.forced_pass for v in verdicts.values())


try:
    from hypothesis import given, settings

    from loopir_strategies import affine_programs

    @given(affine_programs())
    @settings(deadline=None)
    def test_certifier_differential_hypothesis(pap):
        prog, arrays, params = pap
        _dres, plan, traces = _front_end(prog, arrays, params)
        _brute_force_check(prog, plan, traces)
except ImportError:  # pragma: no cover - hypothesis is optional
    pass


# ---------------------------------------------------------------------------
# 2. static_prune: provably behavior-preserving
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(programs.REGISTRY))
def test_static_prune_bit_identical_every_kernel(name):
    bench = programs.get(name)
    prog, arrays, params = bench.make(_small_scale(name))
    spec = "auto" if bench.speculative else "off"
    base = simulator.simulate(
        prog, arrays, params, mode="FUS2", engine="event", speculation=spec
    )
    pruned = simulator.simulate(
        prog, arrays, params, mode="FUS2", engine="event", speculation=spec,
        static_prune=True,
    )
    assert base.cycles == pruned.cycles
    assert sorted(base.arrays) == sorted(pruned.arrays)
    for k in base.arrays:
        np.testing.assert_array_equal(base.arrays[k], pruned.arrays[k])


@pytest.mark.parametrize("engine", ["event", "cycle"])
@pytest.mark.parametrize("trace_mode", ["auto", "interp"])
@pytest.mark.parametrize("mode", ["LSQ", "FUS1", "FUS2"])
def test_static_prune_full_matrix_on_pruning_kernel(mode, engine, trace_mode):
    """tanh+spmv actually loses a pair under static_prune — identical
    cycles/arrays across both engines and trace modes proves the drop is
    timing-invisible, not merely value-preserving. validate_hints rides
    along: the kernel's (truthful) hints pass the dynamic sanitizer."""
    prog, arrays, params = programs.get("tanh+spmv").make(48)
    kw = dict(mode=mode, engine=engine, trace_mode=trace_mode,
              validate_hints=True)
    base = simulator.simulate(prog, arrays, params, **kw)
    pruned = simulator.simulate(prog, arrays, params, static_prune=True, **kw)
    assert base.cycles == pruned.cycles
    for k in base.arrays:
        np.testing.assert_array_equal(base.arrays[k], pruned.arrays[k])


def test_static_prune_plan_shape():
    """The drop lands in ``plan.pruned`` with a ``static:`` reason, the
    kept set shrinks, and ``all_pairs`` (what STA consumes) is unchanged."""
    prog, _a, _p = programs.get("tanh+spmv").make(48)
    base = simulator.Compiled(prog, forwarding=True)
    pruned = simulator.Compiled(prog, forwarding=True, static_prune=True)
    assert len(pruned.plan.pairs) < len(base.plan.pairs)
    reasons = [r for _p2, r in pruned.plan.pruned if r.startswith("static:")]
    assert reasons
    key = lambda p: (p.dst, p.src, p.kind)
    assert sorted(map(key, base.all_pairs)) == sorted(
        map(key, pruned.all_pairs)
    )


# symbolic wave admission: bit-identical batching with enumeration skipped


@pytest.mark.parametrize("seed", range(15))
def test_symbolic_admission_identical_batching_fuzz(seed):
    rng = np.random.default_rng(seed)
    prog, arrays, params = random_wave_program(rng)
    on = executor.build_wave_plan(prog, arrays, params, symbolic_admission=True)
    off = executor.build_wave_plan(prog, arrays, params, symbolic_admission=False)
    np.testing.assert_array_equal(on.req_step, off.req_step)
    assert off.stats.n_sym_requests == 0


@pytest.mark.parametrize("name", ["RAWloop", "stream_dot", "hist+add"])
def test_symbolic_admission_admits_requests_on_registered(name):
    prog, arrays, params = programs.get(name).make(_small_scale(name))
    on = executor.build_wave_plan(prog, arrays, params, symbolic_admission=True)
    off = executor.build_wave_plan(prog, arrays, params, symbolic_admission=False)
    assert on.stats.n_sym_requests > 0 and on.stats.sym_ops
    np.testing.assert_array_equal(on.req_step, off.req_step)


# ---------------------------------------------------------------------------
# 3. contradictory hints: caught statically AND dynamically
# ---------------------------------------------------------------------------


def _lying_hint_program(n=8):
    """Address (n-1)-i strictly decreases inside the innermost loop while
    the hint swears it is monotonic."""
    hint = ir.MonotonicHint(innermost_monotonic=True)
    loop = ir.Loop("i", ir.Const(n), (
        ir.Load("ld_a", "A", ir.Bin("-", ir.Const(n - 1), ir.Var("i")),
                hint=hint),
        ir.Store("st_o", "out", ir.Var("i"), ir.LoadVal("ld_a")),
    ))
    arrays = {
        "A": np.arange(n, dtype=np.float64),
        "out": np.zeros(n, dtype=np.float64),
    }
    return ir.Program("lying_hint", loops=(loop,)), arrays, {}


def _omitted_reset_program(outer=3, inner=4):
    """Address j resets every outer iteration; the hint's explicit
    ``non_monotonic_outer`` omits depth 1, so every reset is a lie."""
    hint = ir.MonotonicHint(
        innermost_monotonic=True, non_monotonic_outer=frozenset()
    )
    loop = ir.Loop("i", ir.Const(outer), (
        ir.Loop("j", ir.Const(inner), (
            ir.Load("ld_a", "A", ir.Var("j"), hint=hint),
            ir.Store("st_o", "out", ir.Var("i") * inner + ir.Var("j"),
                     ir.LoadVal("ld_a")),
        )),
    ))
    arrays = {
        "A": np.arange(inner, dtype=np.float64),
        "out": np.zeros(outer * inner, dtype=np.float64),
    }
    return ir.Program("omitted_reset", loops=(loop,)), arrays, {}


@pytest.mark.parametrize("make", [_lying_hint_program, _omitted_reset_program])
def test_contradictory_hint_caught_statically(make):
    prog, _arrays, _params = make()
    diags = lint.lint_program(prog, kernel=prog.name)
    hits = [d for d in diags if d.code == "RPL001"]
    assert hits and all(d.severity == "error" for d in hits)
    assert any(d.where == "ld_a" for d in hits)


@pytest.mark.parametrize("engine", ["event", "cycle"])
@pytest.mark.parametrize("make", [_lying_hint_program, _omitted_reset_program])
def test_contradictory_hint_caught_dynamically_engines(make, engine):
    prog, arrays, params = make()
    with pytest.raises(deps.HintViolation) as exc:
        simulator.simulate(
            prog, arrays, params, mode="FUS2", engine=engine,
            validate_hints=True,
        )
    assert exc.value.op_id == "ld_a"
    assert exc.value.addr < exc.value.prev_addr
    assert "instance" in str(exc.value)


@pytest.mark.parametrize("make", [_lying_hint_program, _omitted_reset_program])
def test_contradictory_hint_caught_dynamically_executor(make):
    prog, arrays, params = make()
    plan = executor.build_wave_plan(prog, arrays, params)
    with pytest.raises(deps.HintViolation) as exc:
        executor.validate_plan_hints(plan)
    assert exc.value.op_id == "ld_a"
    with pytest.raises(deps.HintViolation):
        executor.execute(prog, arrays, params, validate_hints=True)


def test_truthful_hint_passes_sanitizer_and_resets_allowed():
    """The omitted-reset program becomes legal once the hint admits the
    depth-1 reset — and the linter then flags the hint as redundant
    (RPL002) because the address is fully CR-analyzable."""
    prog, arrays, params = _omitted_reset_program()
    hint = ir.MonotonicHint(
        innermost_monotonic=True, non_monotonic_outer=frozenset({1})
    )
    inner = prog.loops[0].body[0]
    fixed = ir.Program(prog.name, loops=(
        ir.Loop("i", prog.loops[0].trip, (
            ir.Loop("j", inner.trip, (
                ir.Load("ld_a", "A", ir.Var("j"), hint=hint),
            ) + tuple(inner.body[1:])),
        )),
    ))
    res = simulator.simulate(
        fixed, arrays, params, mode="FUS2", validate_hints=True
    )
    assert res.cycles > 0
    plan = executor.build_wave_plan(fixed, arrays, params)
    executor.validate_plan_hints(plan)  # must not raise
    diags = lint.lint_program(fixed, kernel="fixed")
    assert any(d.code == "RPL002" and d.where == "ld_a" for d in diags)
    assert not any(d.code == "RPL001" for d in diags)


def test_check_hint_stream_unit():
    hint = ir.MonotonicHint(innermost_monotonic=True,
                            non_monotonic_outer=frozenset({1}))
    sched = np.array([[0, 0], [0, 1], [1, 0], [1, 1]], dtype=np.int64)
    # reset at the depth-1 advance is legal ...
    deps.check_hint_stream("op", np.array([5, 9, 2, 4]), sched, hint)
    # ... a decrease while only depth 2 advanced is not
    with pytest.raises(deps.HintViolation) as exc:
        deps.check_hint_stream("op", np.array([5, 3, 6, 7]), sched, hint)
    assert exc.value.instance == (0, 1)
    assert exc.value.addr == 3 and exc.value.prev_addr == 5


# ---------------------------------------------------------------------------
# 4. linter stability
# ---------------------------------------------------------------------------


def test_lint_codes_pinned():
    assert sorted(lint.CODES) == [
        "RPL001", "RPL002", "RPL003", "RPL004", "RPL005",
    ]
    assert tuple(lint.SEVERITIES) == ("error", "warning", "info")


def test_lint_all_matches_committed_fixture():
    """``python -m repro.analysis.lint --all`` reproduces the committed
    fixture byte for byte (registered kernels stay lint-clean: no errors
    or warnings, stable info diagnostics)."""
    buf = io.StringIO()
    with redirect_stdout(buf):
        rc = lint.main(["--all"])
    assert rc == 0
    with open(FIXTURE, "r", encoding="utf-8") as f:
        assert buf.getvalue() == f.read()


def test_lint_flags_doomed_fifo_topology():
    """A cross-PE scalar cycle is statically rejected (RPL004)."""
    n = 8
    loops = (
        ir.Loop("i", ir.Const(n), (
            ir.SetLocal("x", ir.Var("i") + ir.Local("y")),
            ir.Store("st_a", "A", ir.Var("i"), ir.Local("x")),
        )),
        ir.Loop("j", ir.Const(n), (
            ir.SetLocal("y", ir.Var("j") + ir.Local("x")),
            ir.Store("st_b", "B", ir.Var("j"), ir.Local("y")),
        )),
    )
    prog = ir.Program("fifo_cycle", loops=loops)
    diags = lint.lint_program(prog, kernel="fifo_cycle")
    assert any(d.code == "RPL004" and d.severity == "error" for d in diags)


# ---------------------------------------------------------------------------
# 5. DSE axis: static_prune folds and caches correctly
# ---------------------------------------------------------------------------


def test_dse_static_prune_axis(tmp_path):
    from repro.dse import cache as cachelib
    from repro.dse import runner
    from repro.dse.spec import SweepSpec

    spec = SweepSpec(
        kernels=("tanh+spmv",), scales={"tanh+spmv": 48},
        modes=("STA", "FUS2"), static_prunes=(False, True),
    )
    pts = spec.points()
    assert len(pts) == 4
    # STA folds the axis (prune_class "-"), FUS2 keys the variants apart
    assert len({p.result_key for p in pts}) == 3
    res = runner.sweep(spec, cache_dir=str(tmp_path))
    assert res.n_unique_runs == 3
    by = {}
    for pr in res.points:
        by.setdefault(pr.point.mode, {})[pr.point.static_prune] = pr.result
    for mode, d in by.items():
        assert d[False].cycles == d[True].cycles, mode
        for k in d[False].arrays:
            np.testing.assert_array_equal(d[False].arrays[k], d[True].arrays[k])
    # second sweep: everything served from the cache
    res2 = runner.sweep(spec, cache_dir=str(tmp_path))
    assert res2.n_cache_hits == 3

    prog, arrays, params = programs.get("tanh+spmv").make(48)
    k_base = cachelib.result_cache_key(
        prog, arrays, params, "FUS2", "event", (), static_prune="-"
    )
    k_prune = cachelib.result_cache_key(
        prog, arrays, params, "FUS2", "event", (), static_prune="prune"
    )
    assert k_base != k_prune
