"""Test-suite conftest: make sibling helper modules (loopir_strategies)
importable from any test file regardless of pytest's rootdir/importmode."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
