"""End-to-end behaviour tests: the fused pipeline reproduces the paper's
headline claim (cross-loop parallelism with exact semantics), training
learns, serving generates, and the multi-device dry-run lowers."""

import os
import subprocess
import sys

import numpy as np
import pytest


def test_paper_headline_fused_speedup_and_exactness():
    """The paper's core claim, end to end: dynamic fusion runs dependent
    sibling loops concurrently (faster than sequential dynamic HLS)
    while preserving sequential semantics exactly."""
    from repro.core import loopir, programs, simulator

    prog, arrays, params = programs.get("RAWloop").make(512)
    oracle = loopir.interpret(prog, arrays, params)
    lsq = simulator.simulate(prog, arrays, params, mode="LSQ")
    fus = simulator.simulate(prog, arrays, params, mode="FUS2", validate=True)
    assert fus.cycles < 0.5 * lsq.cycles  # >2x over sequential dynamic HLS
    for k in oracle:
        np.testing.assert_allclose(fus.arrays[k], oracle[k], atol=1e-12)


@pytest.mark.slow
def test_training_learns_tiny_model(tmp_path):
    from repro.launch import train

    losses = train.main([
        "--arch", "qwen3-14b", "--reduced", "--steps", "30",
        "--batch", "4", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path),
    ])
    assert losses[-1] < losses[0] - 0.3  # actually learning


@pytest.mark.slow
def test_training_resume_exact(tmp_path):
    """Fault-tolerance invariant: 20 straight steps == 10 steps + crash +
    resume + 10 steps (bitwise data stream, same optimizer state)."""
    from repro.launch import train

    a = train.main([
        "--arch", "starcoder2-7b", "--reduced", "--steps", "20",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path / "a"),
        "--ckpt-every", "5",
    ])
    train.main([
        "--arch", "starcoder2-7b", "--reduced", "--steps", "10",
        "--total-steps", "20",  # same LR horizon as the straight run
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path / "b"),
        "--ckpt-every", "5",
    ])
    b = train.main([
        "--arch", "starcoder2-7b", "--reduced", "--steps", "20",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path / "b"),
        "--ckpt-every", "5", "--resume",
    ])
    np.testing.assert_allclose(a[-1], b[-1], rtol=1e-4)


def test_serving_generates():
    from repro.launch import serve

    toks = serve.main([
        "--arch", "gemma3-4b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--max-new", "8",
    ])
    assert toks.shape == (2, 8)
    assert np.asarray(toks).max() > 0


@pytest.mark.slow
def test_multi_device_dryrun_subprocess():
    """Proves the sharding config is coherent on a multi-device mesh
    without polluting this process's device count: a subprocess forces 8
    CPU devices and lowers a reduced config on a 2x4 mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import base as configs
from repro.distributed import partition
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import adamw

mesh = make_host_mesh(2, 4)
cfg = configs.get("qwen3-14b").reduced()
dt = L.FP32
params = T.init_params(jax.random.PRNGKey(0), cfg, dt)
specs = partition.validate_divisibility(partition.param_specs(params), params, mesh)
p_sh = partition.shardings_of(specs, mesh)
params = jax.tree.map(lambda a, s: jax.device_put(a, s), params, p_sh)
opt = adamw.init_state(params)
batch = {
    "tokens": jnp.zeros((8, 64), jnp.int32),
    "targets": jnp.zeros((8, 64), jnp.int32),
}
b_sh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
batch = jax.tree.map(lambda a, s: jax.device_put(a, s), batch, b_sh)
step = jax.jit(steps_lib.make_train_step(cfg, adamw.AdamWConfig(), dt))
params2, opt2, metrics = step(params, opt, batch)
assert jnp.isfinite(metrics["loss"])
# run a second step to prove state threading
params3, opt3, m2 = step(params2, opt2, batch)
print("MULTIDEV_OK", float(metrics["loss"]), float(m2["loss"]))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "MULTIDEV_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_elastic_reshard_subprocess():
    """Checkpoint on an 8-device mesh, restore/reshard on a 4-device
    mesh: topology-independent checkpoints (elastic scaling)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.checkpoint import manager as ckpt
from repro.configs import base as configs
from repro.distributed import partition, elastic
from repro.models import layers as L
from repro.models import transformer as T

cfg = configs.get("starcoder2-7b").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg, L.FP32)
mesh8 = elastic.rebuild_mesh(jax.devices(), prefer_model=4)
sp = partition.validate_divisibility(partition.param_specs(params), params, mesh8)
params = jax.tree.map(lambda a, s: jax.device_put(a, s), params,
                      partition.shardings_of(sp, mesh8))
ckpt.save(params, "/tmp/repro_elastic_test", 1)

# "survivors": only 4 devices
mesh4 = elastic.rebuild_mesh(jax.devices()[:4], prefer_model=2)
like = jax.tree.map(jnp.zeros_like, params)
restored, _ = ckpt.restore(like, "/tmp/repro_elastic_test")
resharded = elastic.reshard_state(restored, mesh4)
import numpy as np
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(resharded)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
