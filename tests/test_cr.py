"""Unit tests for the chain-of-recurrences algebra and §3 monotonicity."""

import pytest

from repro.core import cr
from repro.core import loopir as ir
from repro.core import monotonic as mono


def test_row_major_affine_and_monotonic():
    # paper §3.2: {{0,+,N},+,1} — affine and monotonic
    n = cr.CSym("N", 1, 1000)
    outer = cr.CR(cr.CConst(0), "+", n, 1)
    expr = cr.cr_add(outer, cr.CR(cr.CConst(0), "+", cr.CConst(1), 2))
    assert cr.is_monotonic_expr(expr)
    assert cr.is_affine_expr(expr)


def test_fft_traversal_monotonic_not_affine():
    # paper §3.2: {{0,+,1},+,{2,×,2}} — monotonic, not affine
    stride = cr.CR(cr.CConst(2), "*", cr.CConst(2), 1)
    expr = cr.CR(cr.CR(cr.CConst(0), "+", cr.CConst(1), 1), "+", stride, 2)
    assert cr.is_monotonic_expr(expr)
    assert not cr.is_affine_expr(expr)


def test_negative_step_not_monotonic():
    expr = cr.CR(cr.CConst(100), "+", cr.CConst(-1), 1)
    assert not cr.is_monotonic_expr(expr)


def _analyze(addr, loops):
    prog = ir.Program("t", loops=loops)
    op, path = prog.mem_ops()[0]
    return mono.analyze_op(op, path)


def test_row_major_outer_monotonic():
    # addr = i*M + j with trips (N, M): outer step M == inner step*trip M
    # -> NOT lower -> outer depth monotonic (paper §3.4.1 example)
    m = ir.Param("M", 1, 64)
    loops = (
        ir.Loop("i", ir.Param("N", 1, 64), (
            ir.Loop("j", m, (
                ir.Load("ld", "A", ir.Var("i") * m + ir.Var("j")),
            )),
        )),
    )
    info = _analyze(None, loops)
    assert info.innermost_monotonic
    assert info.non_monotonic == frozenset()
    assert info.affine


def test_column_major_outer_non_monotonic():
    # addr = j*M + i: outer step 1 < inner contribution M*M
    m = ir.Param("M", 2, 64)
    loops = (
        ir.Loop("i", ir.Param("N", 2, 64), (
            ir.Loop("j", m, (
                ir.Load("ld", "A", ir.Var("j") * m + ir.Var("i")),
            )),
        )),
    )
    info = _analyze(None, loops)
    assert info.innermost_monotonic
    assert info.non_monotonic == frozenset({1})


def test_ivar_multiplicative_stride():
    # FFT-style: addr = g * (2*half) + t, half *= 2 per stage:
    # stage depth non-monotonic (reset), inner two depths monotonic
    half = ir.Var("half")
    loops = (
        ir.Loop(
            "s", ir.Param("S", 1, 16),
            (
                ir.Loop("g", ir.Param("G", 1, 64), (
                    ir.Loop("t", half, (
                        ir.Load(
                            "ld", "A",
                            ir.Var("g") * (half * 2) + ir.Var("t"),
                        ),
                    )),
                )),
            ),
            ivars=(ir.IVar("half", ir.Const(1), "*", ir.Const(2)),),
        ),
    )
    info = _analyze(None, loops)
    assert info.innermost_monotonic
    assert not info.affine
    assert 1 in info.non_monotonic  # stage resets addresses
    assert 2 not in info.non_monotonic  # group stride covers the t range


def test_data_dependent_requires_hint():
    loops = (
        ir.Loop("i", ir.Param("N", 1, 64), (
            ir.Load("ld", "A", ir.Read("idx", ir.Var("i"))),
        )),
    )
    info = _analyze(None, loops)
    assert not info.innermost_monotonic
    assert info.non_monotonic == frozenset({1})

    loops_hinted = (
        ir.Loop("i", ir.Param("N", 1, 64), (
            ir.Load(
                "ld", "A", ir.Read("idx", ir.Var("i")),
                hint=ir.MonotonicHint(True, frozenset()),
            ),
        )),
    )
    info2 = _analyze(None, loops_hinted)
    assert info2.innermost_monotonic
    assert info2.from_hint


def test_constant_in_inner_loop_is_monotonic():
    # addr = i (constant in the innermost loop): step 0 -> monotonic
    loops = (
        ir.Loop("i", ir.Param("N", 1, 64), (
            ir.Loop("j", ir.Param("M", 1, 64), (
                ir.Store("st", "A", ir.Var("i"), ir.Const(1.0)),
            )),
        )),
    )
    info = _analyze(None, loops)
    assert info.innermost_monotonic
    assert info.non_monotonic == frozenset()


def test_symbolic_ge():
    half = cr.CR(cr.CConst(1), "*", cr.CConst(2), 1)
    two_half = cr.cr_mul(cr.CConst(2), half)
    assert cr.symbolic_ge(two_half, half)
    assert not cr.symbolic_ge(half, two_half)
    m = cr.CSym("M", 1, cr.INF)
    assert cr.symbolic_ge(m, m)


def test_interval_arithmetic():
    a = cr.Interval(1, 5)
    b = cr.Interval(-2, 3)
    assert (a + b) == cr.Interval(-1, 8)
    assert (a * b) == cr.Interval(-10, 15)
    assert (a - b) == cr.Interval(-2, 7)
