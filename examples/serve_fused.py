"""Batched serving demo: prefill + decode with the monotonic KV-cache
frontier (DESIGN.md §3.2 — append(store)/attend(load) as the paper's
RAW pair). Mixed prompt lengths exercise the per-sequence frontier.

Run:  PYTHONPATH=src python examples/serve_fused.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import base as configs
from repro.launch.serve import serve_batch
from repro.models import layers as L
from repro.models import transformer as T

cfg = configs.get("gemma3-4b").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg, L.FP32)

# mixed-length prompts, right-padded (zeros): lengths are the per-row
# monotonic cache frontier
prompts = jnp.array([
    [5, 9, 12, 7, 3, 0, 0, 0],
    [8, 4, 4, 11, 19, 23, 6, 2],
    [7, 7, 0, 0, 0, 0, 0, 0],
    [3, 14, 15, 9, 2, 6, 0, 0],
], jnp.int32)

toks = serve_batch(cfg, params, prompts, max_new=12, max_seq=32)
print("generated token ids (greedy):")
for i, row in enumerate(toks):
    print(f"  seq{i}: {list(map(int, row))}")
print("(gemma3 reduced config: 5:1 local:global attention with "
      "ring-buffer local caches)")
