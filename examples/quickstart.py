"""Quickstart: the paper's Fig. 1 end to end, in one minute on CPU.

Two sibling loops with a RAW dependency through memory:

    for i in range(n): A[f(i)] = produce(i)     # producer loop
    for j in range(n): out[j] = consume(A[g(j)])  # consumer loop

Static HLS and LSQ-based dynamic HLS must run these sequentially; with
monotonic f(i), dynamic loop fusion overlaps them. This script shows:
  1. the compiler analysis (monotonicity, hazard pairs, pruning),
  2. the cycle-level DU simulation of all four systems (paper Table 1),
  3. a batched design-space sweep over DU sizings (repro.dse),
  4. the TPU adaptation: the same disambiguation as one vectorized
     frontier merge + fused kernel (kernels/du_hazard, fused_stream).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import executor, loopir, monotonic, programs, simulator

# -- 1. compiler analysis ----------------------------------------------------
prog, arrays, params = programs.get("RAWloop").make(2048)
infos = monotonic.analyze_program(prog)
print("== address monotonicity analysis ==")
for info in infos.values():
    print(" ", info.describe())

comp = simulator.Compiled(prog, forwarding=True)
print("\n== hazard plan ==")
print(comp.plan.summary())

# -- 2. the four systems of paper Table 1 ------------------------------------
print("\n== cycle simulation (paper Table 1 structure) ==")
oracle = loopir.interpret(prog, arrays, params)
for mode in ("STA", "LSQ", "FUS1", "FUS2"):
    res = simulator.simulate(prog, arrays, params, mode=mode)
    exact = all(np.allclose(res.arrays[k], oracle[k]) for k in oracle)
    print(f"  {mode:5s}: {res.cycles:7d} cycles   exact={exact}")

# -- 3. design-space sweep: many configurations, one compiled front-end ------
from repro import dse

spec = dse.SweepSpec(
    kernels=["RAWloop"], scales={"RAWloop": 2048}, modes=("STA", "FUS2"),
    sizings={"base": {}, "narrow": {"burst_size": 4},
             "deep": {"burst_size": 32, "dram_latency": 400}},
)
sw = dse.sweep(spec)
print("\n== design-space sweep (repro.dse; DESIGN.md §9) ==")
for row in sw.rows():
    print(f"  {row['mode']:4s} {row['sizing']:6s}: {row['cycles']:7d} cycles "
          f"({row['dram_bursts']} bursts)")
print(f"  {sw.n_points} points -> {sw.n_unique_runs} unique runs, "
      "each bit-identical to a standalone simulate() call")

# -- 3b. speculative AGU: loss-of-decoupling kernels (DESIGN.md §10) ----------
from repro.core import dae as daelib
from repro.core import loopir as ir_mod
from repro.core import programs as programs_mod

sprog, sarrays, sparams = programs_mod.get("spmv_ldtrip").make(64)
try:
    simulator.simulate(sprog, sarrays, sparams, mode="FUS2")
except daelib.LossOfDecoupling as e:
    print("\n== speculative AGU (DESIGN.md §10) ==")
    print(f"  speculation='off' rejects: {e}")
sta = simulator.simulate(
    sprog, sarrays, sparams, mode="STA", speculation="auto"
)
fus = simulator.simulate(
    sprog, sarrays, sparams, mode="FUS2", speculation="auto", validate=True
)
oracle = ir_mod.interpret(sprog, sarrays, sparams)
assert all(np.array_equal(fus.arrays[k], oracle[k]) for k in oracle)
print(f"  speculation='auto': STA {sta.cycles} -> FUS2 {fus.cycles} cycles "
      f"({sta.cycles / fus.cycles:.1f}x), {fus.squashed} squashed phantom "
      "requests, arrays oracle-exact")

# -- 4. TPU adaptation: wave partitioning + fused kernel ----------------------
print("\n== TPU wave executor (Fig. 1c parallelism) ==")
res = executor.execute(prog, arrays, params)
print(f"  {res.stats.n_requests} requests execute in {res.stats.n_waves} "
      f"waves -> {res.stats.parallelism:.0f}x cross-loop parallelism")

import jax.numpy as jnp
from repro.kernels.fused_stream.ops import fused_raw_loops

src = jnp.asarray(np.arange(2048))          # monotonic producer addresses
val = jnp.asarray(arrays["d0"] * 2.0)       # produced values
dst = jnp.asarray(np.arange(2048))          # consumer addresses
mem = jnp.zeros(2048)
vals, hits = fused_raw_loops(src, val, dst, mem, interpret=True)
assert np.allclose(np.asarray(vals), np.asarray(val))
print(f"  Pallas fused kernel: {int(hits.sum())}/{len(dst)} consumer reads "
      "forwarded on-chip, zero sequentialization")
