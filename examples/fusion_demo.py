"""Dynamic loop fusion on a sparse, data-dependent program (bnn).

The addresses come from CSR index arrays — no static analysis can fuse
these loops (paper §3.3); the programmer asserts per-row monotonicity
and the DU disambiguates at runtime. Shows the full compiler pipeline:
DAE decoupling, schedule synthesis, hazard plan, and the measured
speedup of dynamic fusion over static/LSQ HLS.

Run:  PYTHONPATH=src python examples/fusion_demo.py
"""

import numpy as np

from repro.core import dae, loopir, monotonic, programs, schedule, simulator

prog, arrays, params = programs.get("bnn").make(96)

print("== DAE decoupling (paper Fig. 3) ==")
d = dae.decouple(prog)
for pe in d.pes:
    print(f"  PE{pe.id}: loops={[l.var for l in pe.path]} "
          f"mem_ops={pe.mem_ops} AGU_stmts={pe.agu_stmt_count} "
          f"CU_stmts={pe.cu_stmt_count}")

print("\n== program-order schedules (paper §4) ==")
traces = schedule.trace_program(prog, d, arrays, params)
for op_id, tr in list(traces.items())[:2]:
    print(f"  {op_id}: first 5 schedules {tr.sched[:5].tolist()} "
          f"addrs {tr.addr[:5].tolist()}")

print("\n== simulated systems ==")
oracle = loopir.interpret(prog, arrays, params)
results = {}
for mode in ("STA", "LSQ", "FUS1", "FUS2"):
    res = simulator.simulate(prog, arrays, params, mode=mode)
    results[mode] = res.cycles
    assert all(np.allclose(res.arrays[k], oracle[k]) for k in oracle)
    print(f"  {mode:5s}: {res.cycles:7d} cycles")
print(f"\n  dynamic fusion speedup: {results['STA']/results['FUS2']:.1f}x vs "
      f"static HLS, {results['LSQ']/results['FUS2']:.1f}x vs LSQ dynamic HLS")
