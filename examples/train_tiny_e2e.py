"""End-to-end training driver demo: a ~100M-class config trained for a
few hundred steps with the full production stack — sharded data
pipeline, AdamW, async atomic checkpoints, fault-tolerant resume.

On this CPU container we default to a reduced qwen3-family config and
200 steps (a few minutes); pass --full100m for the ~100M variant if you
have the cores/time. The same driver runs any of the ten assigned
architectures (--arch <name>).

Run:  PYTHONPATH=src python examples/train_tiny_e2e.py
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full100m", action="store_true")
    args = ap.parse_args()

    if args.full100m:
        # ~100M params: register a scaled config on the fly
        import dataclasses
        from repro.configs import base as configs

        base = configs.get("qwen3-14b")
        configs.register(dataclasses.replace(
            base, name="qwen3-100m", n_layers=8, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000,
        ))
        losses = train.main([
            "--arch", "qwen3-100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "512", "--ckpt-dir",
            "/tmp/repro_100m_ckpt", "--ckpt-every", "50",
        ])
    else:
        losses = train.main([
            "--arch", "qwen3-14b", "--reduced", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "1e-3",
            "--ckpt-dir", "/tmp/repro_tiny_ckpt", "--ckpt-every", "50",
        ])
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")
