"""Dependence-certifier benchmark: what static proofs buy at plan time.

Produces the evidence file committed as ``BENCH_DEPS.json``:

  * per Table-1 kernel (at ``paper_table1`` scales x ``--scale-mult``),
    the certifier's verdict census over the enumerated hazard pairs and
    how many pairs ``static_prune`` provably drops,
  * hazard-plan build wall-clock with and without the certifier pass
    (the prune pays the certifier once and synthesizes fewer checks),
  * wave-plan symbolic admission: how many of the coarsener's requests
    (and which ops) are admitted by certificate instead of per-address
    enumeration, with end-to-end ``build_wave_plan`` wall-clock both
    ways — the batching is asserted bit-identical while measuring.

Usage:
    PYTHONPATH=src python benchmarks/bench_deps.py --smoke
    PYTHONPATH=src python benchmarks/bench_deps.py \
        --scale-mult 8 --out BENCH_DEPS.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.analysis import deps
from repro.core import dae as daelib
from repro.core import executor
from repro.core import hazards as hz
from repro.core import monotonic as mono
from repro.core import programs
from benchmarks.paper_table1 import SCALES, scaled


def _time(fn, repeat=3):
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_kernel(name: str, scale: int, repeat: int = 3) -> dict:
    prog, arrays, params = programs.get(name).make(scale)
    dres = daelib.decouple(prog)
    infos = mono.analyze_program(prog)

    t_base, plan_base = _time(
        lambda: hz.build_plan(prog, dres, infos, forwarding=True), repeat
    )
    t_prune, plan_prune = _time(
        lambda: hz.build_plan(prog, dres, infos, forwarding=True,
                              static_prune=True),
        repeat,
    )
    enumerated = list(plan_base.pairs) + [p for p, _r in plan_base.pruned]
    verdicts = deps.certify_pairs(prog, enumerated)
    census: dict[str, int] = {deps.NEVER: 0, deps.DISTANCE: 0, deps.UNKNOWN: 0}
    for v in verdicts.values():
        census[v.kind] += 1
    n_static = sum(
        1 for _p, r in plan_prune.pruned if r.startswith("static:")
    )
    assert len(plan_base.pairs) - len(plan_prune.pairs) == n_static

    t_sym, wp_sym = _time(
        lambda: executor.build_wave_plan(prog, arrays, params,
                                         symbolic_admission=True),
        repeat,
    )
    t_enum, wp_enum = _time(
        lambda: executor.build_wave_plan(prog, arrays, params,
                                         symbolic_admission=False),
        repeat,
    )
    np.testing.assert_array_equal(wp_sym.req_step, wp_enum.req_step)

    return {
        "scale": scale,
        "pairs_enumerated": len(enumerated),
        "pairs_kept": len(plan_base.pairs),
        "pairs_static_pruned": n_static,
        "verdicts": {
            "never_conflict": census[deps.NEVER],
            "min_distance": census[deps.DISTANCE],
            "unknown": census[deps.UNKNOWN],
        },
        "plan_wall_base_ms": round(t_base * 1e3, 3),
        "plan_wall_prune_ms": round(t_prune * 1e3, 3),
        "wave": {
            "n_requests": int(len(wp_sym.req_step)),
            "n_sym_requests": int(wp_sym.stats.n_sym_requests),
            "sym_ops": list(wp_sym.stats.sym_ops),
            "wall_sym_s": round(t_sym, 3),
            "wall_enum_s": round(t_enum, 3),
        },
    }


def bench(scale_mult: int = 8, repeat: int = 3) -> dict:
    scales = scaled(scale_mult)
    out: dict = {"scales": scales, "scale_mult": scale_mult, "kernels": {}}
    for name in programs.TABLE1:
        row = bench_kernel(name, scales[name], repeat)
        out["kernels"][name] = row
        print(
            f"{name:10s} pairs {row['pairs_kept']}/"
            f"{row['pairs_enumerated']} kept, {row['pairs_static_pruned']} "
            f"static-pruned; wave {row['wave']['n_sym_requests']}/"
            f"{row['wave']['n_requests']} symbolically admitted "
            f"({row['wave']['wall_enum_s']}s -> {row['wave']['wall_sym_s']}s)",
            flush=True,
        )
    out["total_static_pruned"] = sum(
        r["pairs_static_pruned"] for r in out["kernels"].values()
    )
    out["total_sym_requests"] = sum(
        r["wave"]["n_sym_requests"] for r in out["kernels"].values()
    )
    # the ISSUE's evidence bar: at least one Table-1 kernel benefits
    assert out["total_static_pruned"] >= 1
    assert out["total_sym_requests"] >= 1
    return out


def smoke() -> None:
    """Tier-1 CI smoke: Table 1 at 1x, single repetition, identity
    assertions live in ``bench_kernel``."""
    data = bench(scale_mult=1, repeat=1)
    print(
        f"smoke OK: {len(data['kernels'])} kernels, "
        f"{data['total_static_pruned']} pair(s) static-pruned, "
        f"{data['total_sym_requests']} request(s) symbolically admitted"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_DEPS.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument(
        "--smoke", action="store_true",
        help="tier-1 CI smoke: Table 1 at 1x, identity-asserted, no JSON",
    )
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    data = bench(scale_mult=a.scale_mult, repeat=a.repeat)
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(
        f"wrote {a.out}: {data['total_static_pruned']} pair(s) pruned, "
        f"{data['total_sym_requests']} request(s) symbolically admitted"
    )


if __name__ == "__main__":
    main()
