"""Speculative-AGU benchmark: loss-of-decoupling kernels vs baselines.

Produces the evidence file committed as ``BENCH_SPEC.json``: per
speculative kernel (``programs.SPEC_KERNELS``) at ``--scale-mult`` x
its default scale, cycles for the sequential non-decoupled baseline
(STA — static HLS must schedule a load-fed recurrence at the DRAM
round-trip II) and for LSQ / FUS1 / FUS2 under ``speculation="auto"``,
plus the speculation counters (predictions, mispredictions, squashed
phantom requests) and oracle-exactness of every run.

The headline bar (asserted unless ``--no-assert``): on the
load-dependent-*trip* kernels — where the last-value predictor actually
runs ahead — speculative FUS2 beats the sequential STA baseline.
``chase_sum`` is the documented worst case (a pointer chase mispredicts
every occurrence, degrading to delivery-gated issue; DESIGN.md §10) and
carries ``expected_win: false``.

Usage:
    PYTHONPATH=src:. python benchmarks/bench_speculation.py \
        --scale-mult 8 --out BENCH_SPEC.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import loopir as ir
from repro.core import programs, simulator

# kernels where run-ahead should win vs the sequential baseline; the
# chase is gated per occurrence and documents the worst case
EXPECT_WIN = {"spmv_ldtrip": True, "bfs_front": True, "chase_sum": False}


def _run(prog, arrays, params, mode, validate):
    t0 = time.time()
    res = simulator.simulate(
        prog, arrays, params, mode=mode, engine="event",
        speculation="auto", validate=validate and mode != "STA",
    )
    return time.time() - t0, res


def bench(scale_mult: int = 8, validate: bool = True) -> dict:
    out: dict = {"scale_mult": scale_mult, "kernels": {}}
    for name in programs.SPEC_KERNELS:
        scale = programs.get(name).default_scale * scale_mult
        prog, arrays, params = programs.get(name).make(scale)
        load_streams: dict = {}

        def hook(op_id, addr, is_store, valid, value):
            if not is_store:
                load_streams.setdefault(op_id, []).append(value)

        oracle = ir.interpret(prog, arrays, params, trace_hook=hook)
        row: dict = {
            "scale": scale,
            "expected_win": EXPECT_WIN.get(name, True),
        }
        for mode in ("STA", "LSQ", "FUS1", "FUS2"):
            wall, res = _run(prog, arrays, params, mode, validate)
            for k in oracle:
                np.testing.assert_array_equal(
                    res.arrays[k], oracle[k],
                    err_msg=f"{name}/{mode}: diverged from oracle ({k})",
                )
            row[mode] = {
                "cycles": res.cycles,
                "dram_requests": res.dram_requests,
                "squashed": res.squashed,
                "wall_s": round(wall, 3),
            }
        row["speedup_fus2_vs_sta"] = round(
            row["STA"]["cycles"] / max(row["FUS2"]["cycles"], 1), 2
        )
        row["speedup_fus2_vs_lsq"] = round(
            row["LSQ"]["cycles"] / max(row["FUS2"]["cycles"], 1), 2
        )
        # speculation counters come from the shared trace front-end
        # (reusing the hooked oracle walk above — no second interpret)
        from repro.core import dae as daelib
        from repro.core import schedule as schedlib

        dae = daelib.decouple(prog, speculation="auto")
        spec_out: list = []
        schedlib.trace_program(
            prog, dae, arrays, params, spec_out=spec_out,
            oracle_loads=load_streams,
        )
        row["speculation"] = spec_out[0].summary()
        out["kernels"][name] = row
        print(
            f"{name:12s} @{scale}: STA {row['STA']['cycles']} -> "
            f"FUS2+spec {row['FUS2']['cycles']} "
            f"({row['speedup_fus2_vs_sta']}x, "
            f"{row['speculation']['mispredictions']}/"
            f"{row['speculation']['predictions']} mispredicted, "
            f"{row['FUS2']['squashed']} squashed)",
            flush=True,
        )
    return out


def check_bar(data: dict) -> None:
    for name, row in data["kernels"].items():
        if row["expected_win"]:
            assert row["FUS2"]["cycles"] < row["STA"]["cycles"], (
                f"{name}: speculative FUS2 ({row['FUS2']['cycles']}) did "
                f"not beat the sequential baseline ({row['STA']['cycles']})"
            )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_SPEC.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--no-assert", action="store_true")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tier-1 CI smoke: tiny scales, oracle-asserted, no JSON",
    )
    a = ap.parse_args()
    if a.smoke:
        data = bench(scale_mult=1, validate=True)
        check_bar(data)
        print(f"smoke OK: {len(data['kernels'])} speculative kernels")
        return
    data = bench(scale_mult=a.scale_mult)
    if not a.no_assert:
        check_bar(data)
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    wins = [
        r["speedup_fus2_vs_sta"]
        for r in data["kernels"].values()
        if r["expected_win"]
    ]
    print(f"wrote {a.out}: FUS2+spec vs STA speedups {wins}")


if __name__ == "__main__":
    main()
