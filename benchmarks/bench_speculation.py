"""Speculative-AGU benchmark: loss-of-decoupling kernels vs baselines.

Produces the evidence file committed as ``BENCH_SPEC.json``: per
speculative kernel (``programs.SPEC_KERNELS``) at ``--scale-mult`` x
its default scale, cycles for the sequential non-decoupled baseline
(STA — static HLS must schedule a load-fed recurrence at the DRAM
round-trip II), LSQ / FUS1 at the default (``auto``) predictor, and
FUS2 across the whole predictor zoo (``--predictor``, default
``all`` = every ``dae.PREDICTORS`` value) — plus the per-predictor
speculation stats (``SimResult.spec_stats``) and oracle-exactness of
every run.

The headline bar (asserted unless ``--no-assert``): on every
speculative kernel, FUS2 under its *best* predictor beats the
sequential STA baseline. That includes ``chase_sum`` — a non-win under
last-value prediction (PR 4's documented worst case) — because the
context-table predictor learns the pointer chain on the first lap and
runs ahead on the rest, and ``strided_scan``, which only the stride
predictor opens up (DESIGN.md §10).

Usage:
    PYTHONPATH=src:. python benchmarks/bench_speculation.py \
        --scale-mult 8 --out BENCH_SPEC.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import dae as daelib
from repro.core import loopir as ir
from repro.core import programs, simulator

# every speculative kernel is expected to beat sequential STA under its
# best predictor: trip speculation (spmv_ldtrip, bfs_front) wins under
# any of them; chase_sum needs the context table; strided_scan the
# stride predictor
EXPECT_WIN = {
    "spmv_ldtrip": True,
    "bfs_front": True,
    "chase_sum": True,
    "strided_scan": True,
}


def _run(prog, arrays, params, mode, validate, predictor="auto"):
    t0 = time.time()
    res = simulator.simulate(
        prog, arrays, params, mode=mode, engine="event",
        speculation="auto", predictor=predictor,
        validate=validate and mode != "STA",
    )
    return time.time() - t0, res


def bench(
    scale_mult: int = 8,
    validate: bool = True,
    predictors=daelib.PREDICTORS,
) -> dict:
    out: dict = {"scale_mult": scale_mult, "kernels": {}}
    for name in programs.SPEC_KERNELS:
        scale = programs.get(name).default_scale * scale_mult
        prog, arrays, params = programs.get(name).make(scale)
        oracle = ir.interpret(prog, arrays, params)
        row: dict = {
            "scale": scale,
            "expected_win": EXPECT_WIN.get(name, True),
        }

        def check(mode_label, res):
            for k in oracle:
                np.testing.assert_array_equal(
                    res.arrays[k], oracle[k],
                    err_msg=f"{name}/{mode_label}: diverged from oracle ({k})",
                )

        for mode in ("STA", "LSQ", "FUS1"):
            wall, res = _run(prog, arrays, params, mode, validate)
            check(mode, res)
            row[mode] = {
                "cycles": res.cycles,
                "dram_requests": res.dram_requests,
                "squashed": res.squashed,
                "wall_s": round(wall, 3),
            }
        row["predictors"] = {}
        for pred in predictors:
            wall, res = _run(prog, arrays, params, "FUS2", validate, pred)
            check(f"FUS2/{pred}", res)
            row["predictors"][pred] = {
                "FUS2": {
                    "cycles": res.cycles,
                    "dram_requests": res.dram_requests,
                    "squashed": res.squashed,
                    "wall_s": round(wall, 3),
                },
                "speculation": res.spec_stats,
            }
        best = min(
            row["predictors"], key=lambda p: row["predictors"][p]["FUS2"]["cycles"]
        )
        best_cycles = row["predictors"][best]["FUS2"]["cycles"]
        row["best_predictor"] = best
        row["speedup_fus2_vs_sta"] = round(
            row["STA"]["cycles"] / max(best_cycles, 1), 2
        )
        row["speedup_fus2_vs_lsq"] = round(
            row["LSQ"]["cycles"] / max(best_cycles, 1), 2
        )
        out["kernels"][name] = row
        per_pred = " ".join(
            f"{p}={row['predictors'][p]['FUS2']['cycles']}"
            for p in row["predictors"]
        )
        print(
            f"{name:12s} @{scale}: STA {row['STA']['cycles']} -> "
            f"FUS2+spec best={best} {best_cycles} "
            f"({row['speedup_fus2_vs_sta']}x vs STA) [{per_pred}]",
            flush=True,
        )
    return out


def check_bar(data: dict) -> None:
    for name, row in data["kernels"].items():
        if row["expected_win"]:
            best = min(
                p["FUS2"]["cycles"] for p in row["predictors"].values()
            )
            assert best < row["STA"]["cycles"], (
                f"{name}: best-predictor speculative FUS2 ({best}) did "
                f"not beat the sequential baseline ({row['STA']['cycles']})"
            )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_SPEC.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--no-assert", action="store_true")
    ap.add_argument(
        "--predictor", default="all",
        choices=("all",) + daelib.PREDICTORS,
        help="FUS2 predictor axis: one predictor, or 'all' (default)",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tier-1 CI smoke: tiny scales, full predictor sweep, "
        "oracle-asserted, no JSON",
    )
    a = ap.parse_args()
    preds = daelib.PREDICTORS if a.predictor == "all" else (a.predictor,)
    if a.smoke:
        data = bench(scale_mult=1, validate=True, predictors=preds)
        check_bar(data)
        print(
            f"smoke OK: {len(data['kernels'])} speculative kernels x "
            f"{len(preds)} predictors"
        )
        return
    data = bench(scale_mult=a.scale_mult, predictors=preds)
    if not a.no_assert:
        check_bar(data)
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    wins = {
        k: r["speedup_fus2_vs_sta"]
        for k, r in data["kernels"].items()
        if r["expected_win"]
    }
    print(f"wrote {a.out}: best-predictor FUS2+spec vs STA speedups {wins}")


if __name__ == "__main__":
    main()
