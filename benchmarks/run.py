"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * table1_*       — paper Table 1 (cycles per mode + speedups)
  * fig5_pruning   — hazard-pair pruning on the FFT code (Fig. 5)
  * forwarding_*   — §7.3.2 store-to-load forwarding impact
  * wave_*         — TPU wave-executor parallelism (Fig. 1c analogue)
  * kernel_*       — Pallas kernel microbenches (interpret mode walltime;
    shape-correctness is the signal on CPU, not speed)
  * roofline summary — dry-run cell counts (full tables in EXPERIMENTS.md)
"""

from __future__ import annotations

import time

import numpy as np


def _t(fn, *args, reps=3, **kw):
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_table1(emit, scale_mult=1, engine="event", scales=None,
                 trace_mode="auto"):
    from benchmarks.paper_table1 import run_table, scaled, summarize

    rows = run_table(
        scales=scales or scaled(scale_mult), engine=engine,
        trace_mode=trace_mode,
    )
    for r in rows:
        emit(
            f"table1_{r['kernel']}",
            r["FUS2_wall_s"] * 1e6,
            f"STA={r['STA']};LSQ={r['LSQ']};FUS1={r['FUS1']};FUS2={r['FUS2']}"
            f";fus2_vs_lsq={r['LSQ']/r['FUS2']:.2f}"
            f";fus2_vs_sta={r['STA']/r['FUS2']:.2f}",
        )
    s = summarize(rows)
    emit(
        "table1_hmean", 0,
        f"fus2_vs_lsq={s['FUS2_vs_LSQ_hmean']:.2f}"
        f";fus2_vs_sta={s['FUS2_vs_STA_hmean']:.2f}"
        f";paper=4x_and_14x",
    )


def bench_pruning(emit):
    from repro.core import dae, hazards, monotonic, programs

    for name in ("fft", "matpower", "pagerank"):
        prog, arrays, params = programs.get(name).make(
            64 if name != "fft" else 64
        )
        d = dae.decouple(prog)
        infos = monotonic.analyze_program(prog)
        us, plan = _t(
            hazards.build_plan, prog, d, infos, True, reps=3
        )
        total = len(plan.pairs) + len(plan.pruned)
        emit(
            f"fig5_pruning_{name}", us,
            f"enumerated={total};kept={len(plan.pairs)};pruned={len(plan.pruned)}",
        )


def bench_forwarding(emit):
    from repro.core import programs, simulator

    for name in ("hist+add", "matpower", "fft"):
        prog, arrays, params = programs.get(name).make(64)
        f1 = simulator.simulate(prog, arrays, params, mode="FUS1")
        f2 = simulator.simulate(prog, arrays, params, mode="FUS2")
        emit(
            f"forwarding_{name}", 0,
            f"fus1={f1.cycles};fus2={f2.cycles}"
            f";speedup={f1.cycles/f2.cycles:.2f};forwards={f2.forwards}",
        )


def bench_waves(emit):
    from repro.core import executor, programs

    for name in programs.all_names():
        scale = 64 if name == "fft" else 96
        prog, arrays, params = programs.get(name).make(scale)
        spec = "auto" if programs.get(name).speculative else "off"
        us, res = _t(
            executor.execute, prog, arrays, params, speculation=spec, reps=1
        )
        emit(
            f"wave_{name}", us,
            f"requests={res.stats.n_requests};waves={res.stats.n_waves}"
            f";parallelism={res.stats.parallelism:.1f}",
        )


def bench_kernels(emit):
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    from repro.kernels.du_hazard.ops import hazard_frontier
    src = jnp.sort(jax.random.randint(ks[0], (4096,), 0, 2048))
    dst = jax.random.randint(ks[1], (4096,), 0, 2048)
    us, _ = _t(
        lambda: jax.block_until_ready(
            hazard_frontier(src, dst, interpret=True)
        ), reps=2,
    )
    emit("kernel_du_hazard_4k", us, "interpret=True")

    from repro.kernels.moe_group_mm.kernel import group_matmul
    x = jax.random.normal(ks[2], (512, 64))
    w = jax.random.normal(ks[3], (8, 64, 64)) * 0.1
    be = jax.random.randint(ks[4], (16,), 0, 8).astype(jnp.int32)
    us, _ = _t(
        lambda: jax.block_until_ready(
            group_matmul(x, w, be, block_t=32, interpret=True)
        ), reps=2,
    )
    emit("kernel_moe_group_mm", us, "8e_512t_interpret")

    from repro.kernels.attention.ops import flash_attention
    q = jax.random.normal(ks[5], (4, 256, 64))
    k = jax.random.normal(ks[6], (4, 256, 64))
    v = jax.random.normal(ks[7], (4, 256, 64))
    us, _ = _t(
        lambda: jax.block_until_ready(
            flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
        ), reps=2,
    )
    emit("kernel_flash_attention", us, "4x256x64_interpret")


def bench_roofline_summary(emit):
    from benchmarks import roofline

    cells = roofline.load()
    if not cells:
        emit("roofline_cells", 0, "missing_run_dryrun_first")
        return
    s = roofline.summary(cells)
    emit(
        "roofline_cells", 0,
        f"ok={s['ok']};skipped={s['skipped']};errors={s['errors']}",
    )


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="small-scale CI smoke: Table 1 + pruning only",
    )
    ap.add_argument(
        "--scale-mult", type=int, default=1,
        help="run Table 1 at N x the default scales (event engine "
        "sustains >= 8x; see BENCH_ENGINE.json)",
    )
    ap.add_argument("--engine", choices=("cycle", "event"), default="event")
    ap.add_argument(
        "--trace-mode", choices=("auto", "compiled", "interp"),
        default="auto", help="AGU/CU front-end path (DESIGN.md §7)",
    )
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us:.1f},{derived}", flush=True)

    if args.smoke:
        from benchmarks.paper_table1 import scaled

        smoke_scales = {k: max(v // 8, 16) for k, v in scaled(1).items()}
        smoke_scales["fft"] = 64
        bench_table1(emit, engine=args.engine, scales=smoke_scales,
                     trace_mode=args.trace_mode)
        bench_pruning(emit)
        return

    bench_table1(emit, scale_mult=args.scale_mult, engine=args.engine,
                 trace_mode=args.trace_mode)
    bench_pruning(emit)
    bench_forwarding(emit)
    bench_waves(emit)
    bench_kernels(emit)
    bench_roofline_summary(emit)


if __name__ == "__main__":
    main()
