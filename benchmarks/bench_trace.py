"""Trace-compiler benchmark: AGU/CU front-end, interp vs compiled.

Produces the evidence file committed as ``BENCH_TRACE.json``:

  * per Table-1 kernel at ``--scale-mult`` (default 8x), wall-clock of
    ``schedule.trace_program`` with ``mode="interp"`` (the per-iteration
    Python IR walk) vs ``mode="compiled"`` (the closed-form numpy path,
    core/affine.py), with exact-equality verification of every stream,
  * the per-PE path report under ``trace_mode="auto"`` — the acceptance
    bar is every PE of every kernel on the compiled path,
  * CU construction time: generator CUs (which for load-free PEs run to
    completion when primed) vs ``dae.make_cu``'s vectorized VecCU.

Usage:
    PYTHONPATH=src:. python benchmarks/bench_trace.py \
        --out BENCH_TRACE.json --scale-mult 8
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import dae as daelib
from repro.core import programs
from repro.core import schedule as schedlib
from benchmarks.paper_table1 import scaled


def _time(fn, reps=1):
    best = None
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best, out


def _traces_equal(a, b) -> bool:
    if set(a) != set(b):
        return False
    for op_id in a:
        x, y = a[op_id], b[op_id]
        if (
            x.depth != y.depth
            or x.is_store != y.is_store
            or x.pe_id != y.pe_id
            or not np.array_equal(x.sched, y.sched)
            or not np.array_equal(x.addr, y.addr)
            or not np.array_equal(x.lastiter, y.lastiter)
            or not np.array_equal(x.seq, y.seq)
        ):
            return False
    return True


def bench(scale_mult: int = 8, reps: int = 2) -> dict:
    scales = scaled(scale_mult)
    out: dict = {"scale_mult": scale_mult, "scales": scales, "kernels": {}}
    for name in programs.TABLE1:
        prog, arrays, params = programs.get(name).make(scales[name])
        d = daelib.decouple(prog)

        t_i, tr_i = _time(
            lambda: schedlib.trace_program(
                prog, d, arrays, params, mode="interp"
            ),
            reps=reps,
        )
        report: dict = {}
        t_c, tr_c = _time(
            lambda: schedlib.trace_program(
                prog, d, arrays, params, mode="compiled", report=report
            ),
            reps=reps,
        )

        # CU construction: generator (interp) vs make_cu (auto -> VecCU
        # for load-free value chains)
        t_cu_i, _ = _time(
            lambda: [daelib.CU(pe, arrays, params) for pe in d.pes], reps=reps
        )
        t_cu_v, cus = _time(
            lambda: [daelib.make_cu(pe, arrays, params) for pe in d.pes],
            reps=reps,
        )

        row = {
            "scale": scales[name],
            "requests": int(sum(t.n_req for t in tr_i.values())),
            "pes": len(d.pes),
            "interp_s": round(t_i, 4),
            "compiled_s": round(t_c, 4),
            "speedup": round(t_i / max(t_c, 1e-9), 1),
            "exact_equal": _traces_equal(tr_i, tr_c),
            "paths": {
                str(pe): rep["path"] for pe, rep in sorted(report.items())
            },
            "vec_cu_pes": sum(
                1 for cu in cus if type(cu).__name__ == "VecCU"
            ),
            "cu_interp_s": round(t_cu_i, 4),
            "cu_auto_s": round(t_cu_v, 4),
        }
        out["kernels"][name] = row
        print(
            f"{name:10s} reqs={row['requests']:7d} "
            f"interp={row['interp_s']:.3f}s compiled={row['compiled_s']:.4f}s "
            f"speedup={row['speedup']:6.1f}x exact={row['exact_equal']} "
            f"veccu={row['vec_cu_pes']}/{row['pes']}",
            flush=True,
        )

    rows = out["kernels"].values()
    out["all_compiled"] = all(
        p == "compiled" for r in rows for p in r["paths"].values()
    )
    out["all_exact"] = all(r["exact_equal"] for r in rows)
    out["min_speedup"] = min(r["speedup"] for r in rows)
    out["target_speedup"] = 10.0
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_TRACE.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--reps", type=int, default=2)
    a = ap.parse_args()
    data = bench(scale_mult=a.scale_mult, reps=a.reps)
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    # the acceptance bars, enforced so the CI step fails on regression
    assert data["all_exact"], "compiled traces diverged from the interpreter"
    assert data["all_compiled"], (
        "a Table-1 kernel fell off the compiled path: "
        + str({k: r["paths"] for k, r in data["kernels"].items()})
    )
    assert data["min_speedup"] >= data["target_speedup"], (
        f"trace-construction speedup regressed: min {data['min_speedup']}x "
        f"< target {data['target_speedup']}x"
    )
    print(
        f"wrote {a.out}: min speedup {data['min_speedup']}x "
        f"(target >= {data['target_speedup']}x), "
        f"all_compiled={data['all_compiled']}"
    )


if __name__ == "__main__":
    main()
