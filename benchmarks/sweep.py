"""Design-space sweep benchmark: batched DSE engine vs looped simulate().

Produces the evidence file committed as ``BENCH_DSE.json``:

  * a >=32-point sweep over the nine Table-1 kernels at ``--scale-mult``
    (modes x trace modes x DU sizings, plus an STA engine-axis grid),
  * the **looped baseline**: one standalone ``simulate()`` call per
    point, exactly as a pre-DSE harness would script it,
  * the batched run (``repro.dse.sweep``): cold serial, cold parallel
    (``--workers``), and warm (cache) wall-clock,
  * **bit-identity verification**: every sweep point's SimResult
    (cycles, DRAM traffic, forwards, and a sha256 of every final
    array) equals its standalone call,
  * per-kernel speedups/Pareto sizings (``launch.analysis``) and the
    config-batched §5.5 slack profile.

Acceptance bars asserted at the end (mirroring bench_trace.py): exact
per-point identity and >=5x cold sweep throughput vs. the loop.

Usage:
    PYTHONPATH=src:. python benchmarks/sweep.py --out BENCH_DSE.json \
        --scale-mult 8
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.paper_table1 import scaled
from repro import dse
from repro.core import programs, simulator
from repro.launch import analysis

# DU sizings x calibration knobs. The last three vary parameters only
# some modes read (dse.spec.MODE_SIM_FIELDS): sta-ii-* move the STA
# static-II calibration (dynamic modes provably unaffected), fwd-4 the
# §5.5 forwarding latency (only FUS2 reads it) — the planner re-runs
# exactly the modes each knob can affect, the loop baseline re-runs
# everything.
SIZINGS = {
    "base": {},
    "narrow": {"burst_size": 4, "dram_latency": 100},
    "deep": {"burst_size": 32, "dram_latency": 400},
    "sta-ii-120": {"sta_mem_dep_ii": 120},
    "sta-ii-240": {"sta_mem_dep_ii": 240},
    "fwd-4": {"forward_latency": 4},
}


def build_spec(scales: dict) -> dse.SweepSpec:
    """The evidence sweep: 9 kernels x (3 modes x 3 trace modes x 6
    sizings) + an STA engine-axis grid (STA is engine-invariant — the
    planner dedups it; the loop baseline pays for every point)."""
    kernels = list(programs.TABLE1)
    return dse.SweepSpec(
        kernels=kernels,
        scales=scales,
        modes=("STA", "FUS1", "FUS2"),
        trace_modes=("auto", "compiled", "interp"),
        sizings=SIZINGS,
        extra=(
            dse.SweepSpec(
                kernels=kernels, scales=scales, modes=("STA",),
                engines=("cycle",), trace_modes=("auto", "interp"),
                sizings=SIZINGS,
            ),
        ),
    )


def _sig(res: simulator.SimResult) -> dict:
    """Comparable signature of a SimResult; arrays by content hash so
    the baseline needn't stay resident."""
    h = {}
    for k in sorted(res.arrays):
        a = np.ascontiguousarray(res.arrays[k])
        h[k] = hashlib.sha256(
            a.dtype.str.encode() + repr(a.shape).encode() + a.tobytes()
        ).hexdigest()
    return {
        "cycles": res.cycles, "dram_bursts": res.dram_bursts,
        "dram_requests": res.dram_requests, "forwards": res.forwards,
        "arrays": h,
    }


def run_baseline(points) -> tuple[float, dict]:
    """The pre-DSE harness: one full simulate() per point, re-compiling
    everything every time."""
    sigs = {}
    t0 = time.perf_counter()
    for p in points:
        prog, arrays, params = programs.get(p.kernel).make(p.scale)
        res = simulator.simulate(
            prog, arrays, params, mode=p.mode, sim=p.sim_params(),
            engine=p.engine, trace_mode=p.trace_mode,
        )
        sigs[p.point_id] = _sig(res)
    return time.perf_counter() - t0, sigs


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_DSE.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument(
        "--workers", type=int, default=0,
        help="parallel group workers for the headline run (0 = cpu count)",
    )
    ap.add_argument(
        "--skip-serial", action="store_true",
        help="skip the cold serial sweep measurement",
    )
    ap.add_argument(
        "--target-speedup", type=float, default=5.0,
        help="cold-sweep throughput bar to assert (the committed "
        "BENCH_DSE.json evidence uses the default 5.0 at --scale-mult "
        "8; CI canary runs at smaller scales assert a lower bar since "
        "shared-artifact amortization shrinks with scale)",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny scales, correctness-only (no speedup bar): CI gate",
    )
    a = ap.parse_args(argv)

    workers = a.workers or (os.cpu_count() or 1)
    if a.smoke:
        scales = {k: max(v // 16, 16) for k, v in scaled(1).items()}
        scales["fft"] = 64
    else:
        scales = scaled(a.scale_mult)
    spec = build_spec(scales)
    points = spec.points()
    print(f"sweep: {len(points)} points over {len(programs.TABLE1)} kernels "
          f"at scales {scales}", flush=True)

    base_wall, base_sigs = run_baseline(points)
    print(f"baseline loop: {base_wall:.1f}s "
          f"({base_wall / len(points):.2f}s/point)", flush=True)

    walls = {}
    if not a.skip_serial:
        with tempfile.TemporaryDirectory() as td:
            t0 = time.perf_counter()
            res_serial = dse.sweep(spec, cache_dir=td, workers=1)
            walls["cold_serial_s"] = time.perf_counter() - t0
        print(f"dse cold serial: {walls['cold_serial_s']:.1f}s "
              f"({res_serial.n_unique_runs} unique runs)", flush=True)

    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        res = dse.sweep(spec, cache_dir=td, workers=workers, profile=True)
        walls["cold_parallel_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_warm = dse.sweep(spec, cache_dir=td, workers=1)
        walls["warm_s"] = time.perf_counter() - t0
    print(f"dse cold x{workers} workers: {walls['cold_parallel_s']:.1f}s; "
          f"warm: {walls['warm_s']:.1f}s "
          f"({res_warm.n_cache_hits}/{res_warm.n_unique_runs} hits)",
          flush=True)

    # --- bit-identity of every point vs its standalone call ---------------
    mismatches = []
    for pr in res.points:
        if _sig(pr.result) != base_sigs[pr.point.point_id]:
            mismatches.append(pr.point.point_id)
    identical = not mismatches
    print(f"bit-identity: {len(res.points) - len(mismatches)}/"
          f"{len(res.points)} points identical", flush=True)

    rows = res.rows()
    data = {
        "scale_mult": a.scale_mult if not a.smoke else 0,
        "smoke": a.smoke,
        "scales": scales,
        "n_points": len(points),
        "n_unique_runs": res.n_unique_runs,
        "workers": workers,
        "baseline_loop_s": round(base_wall, 2),
        **{k: round(v, 2) for k, v in walls.items()},
        "speedup_parallel": round(base_wall / walls["cold_parallel_s"], 2),
        "speedup_warm": round(base_wall / max(walls["warm_s"], 1e-9), 1),
        "target_speedup": a.target_speedup,
        "all_points_bit_identical": identical,
        "summary": analysis.summarize_sweep(rows),
        "forward_slack_profile": res.profile,
        "group_stats": res.groups,
    }
    if "cold_serial_s" in walls:
        data["speedup_serial"] = round(base_wall / walls["cold_serial_s"], 2)

    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)

    assert identical, f"sweep diverged from standalone simulate(): {mismatches[:5]}"
    if not a.smoke:
        assert data["speedup_parallel"] >= data["target_speedup"], (
            f"sweep throughput regressed: {data['speedup_parallel']}x "
            f"< target {data['target_speedup']}x vs the looped baseline"
        )
    print(
        f"wrote {a.out}: {data['speedup_parallel']}x cold "
        f"(serial {data.get('speedup_serial', '-')}x, warm "
        f"{data['speedup_warm']}x) vs looped simulate(); "
        f"bit-identical={identical}"
    )
    return data


if __name__ == "__main__":
    main()
