"""Design-space sweep benchmark: batched DSE engine vs looped simulate().

Produces the evidence file committed as ``BENCH_DSE.json``:

  * a >=32-point sweep over the nine Table-1 kernels at ``--scale-mult``
    (modes x trace modes x DU sizings, plus an STA engine-axis grid),
  * the **looped baseline**: one standalone ``simulate()`` call per
    point, exactly as a pre-DSE harness would script it,
  * the batched run (``repro.dse.sweep``): cold serial, cold parallel
    (``--workers``), and warm (cache) wall-clock,
  * **bit-identity verification**: every sweep point's SimResult
    (cycles, DRAM traffic, forwards, and a sha256 of every final
    array) equals its standalone call,
  * per-kernel speedups/Pareto sizings (``launch.analysis``) and the
    config-batched §5.5 slack profile.

Sweep-service flags (DESIGN.md §13):

  * ``--shard i/n`` runs only shard ``i`` of an ``n``-way
    ``dse.shard_plan`` partition (multi-host use; pair with
    ``--cache-dir`` and merge with ``dse.merge_results``),
  * ``--resume`` re-plans from the surviving ``--cache-dir`` after an
    interrupted run (only missing unique runs execute),
  * ``--stream`` prints each point as it lands plus the live partial
    Pareto front size (``launch.analysis.ParetoTracker``),
  * ``--shard-check N`` re-runs the sweep as N shards in fresh caches
    and asserts ``merge_results`` equals the single-host result
    bit-for-bit (the nightly 594-point gate uses ``--shard-check 2``),
  * ``--differential`` turns on per-point differential validation.

Acceptance bars asserted at the end (mirroring bench_trace.py): exact
per-point identity and >=5x cold sweep throughput vs. the loop. The
``--smoke`` CI gate additionally asserts the shard+merge identity, a
kill+resume round trip (child sweep SIGKILLed mid-run, resumed from
the surviving cache, bit-identical to uninterrupted), and that the
streaming Pareto front's every prefix matches the batch recompute.

Usage:
    PYTHONPATH=src:. python benchmarks/sweep.py --out BENCH_DSE.json \
        --scale-mult 8
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.paper_table1 import scaled
from repro import dse
from repro.core import programs, simulator
from repro.launch import analysis

# DU sizings x calibration knobs. The last three vary parameters only
# some modes read (dse.spec.MODE_SIM_FIELDS): sta-ii-* move the STA
# static-II calibration (dynamic modes provably unaffected), fwd-4 the
# §5.5 forwarding latency (only FUS2 reads it) — the planner re-runs
# exactly the modes each knob can affect, the loop baseline re-runs
# everything.
SIZINGS = {
    "base": {},
    "narrow": {"burst_size": 4, "dram_latency": 100},
    "deep": {"burst_size": 32, "dram_latency": 400},
    "sta-ii-120": {"sta_mem_dep_ii": 120},
    "sta-ii-240": {"sta_mem_dep_ii": 240},
    "fwd-4": {"forward_latency": 4},
}


def build_spec(scales: dict) -> dse.SweepSpec:
    """The evidence sweep: 9 kernels x (3 modes x 3 trace modes x 6
    sizings) + an STA engine-axis grid (STA is engine-invariant — the
    planner dedups it; the loop baseline pays for every point)."""
    kernels = list(programs.TABLE1)
    return dse.SweepSpec(
        kernels=kernels,
        scales=scales,
        modes=("STA", "FUS1", "FUS2"),
        trace_modes=("auto", "compiled", "interp"),
        sizings=SIZINGS,
        extra=(
            dse.SweepSpec(
                kernels=kernels, scales=scales, modes=("STA",),
                engines=("cycle",), trace_modes=("auto", "interp"),
                sizings=SIZINGS,
            ),
        ),
    )


def _sig(res: simulator.SimResult) -> dict:
    """Comparable signature of a SimResult; arrays by content hash so
    the baseline needn't stay resident."""
    h = {}
    for k in sorted(res.arrays):
        a = np.ascontiguousarray(res.arrays[k])
        h[k] = hashlib.sha256(
            a.dtype.str.encode() + repr(a.shape).encode() + a.tobytes()
        ).hexdigest()
    return {
        "cycles": res.cycles, "dram_bursts": res.dram_bursts,
        "dram_requests": res.dram_requests, "forwards": res.forwards,
        "arrays": h,
    }


def run_baseline(points) -> tuple[float, dict]:
    """The pre-DSE harness: one full simulate() per point, re-compiling
    everything every time."""
    sigs = {}
    t0 = time.perf_counter()
    for p in points:
        prog, arrays, params = programs.get(p.kernel).make(p.scale)
        res = simulator.simulate(
            prog, arrays, params, mode=p.mode, sim=p.sim_params(),
            engine=p.engine, trace_mode=p.trace_mode,
        )
        sigs[p.point_id] = _sig(res)
    return time.perf_counter() - t0, sigs


def _same_result(a: dse.SweepResult, b: dse.SweepResult) -> list:
    """Point ids where two sweep results differ (bit-level)."""
    bad = []
    assert len(a.points) == len(b.points)
    for pa, pb in zip(a.points, b.points):
        if (pa is None) != (pb is None):
            bad.append((pa or pb).point.point_id)
        elif pa is not None and _sig(pa.result) != _sig(pb.result):
            bad.append(pa.point.point_id)
    return bad


def check_shard_merge(spec, whole: dse.SweepResult, n_shards: int) -> dict:
    """Run the sweep as ``n_shards`` independent shards (fresh caches),
    merge with ``dse.merge_results``, assert bit-identity with the
    single-host result."""
    plan = dse.shard_plan(spec, n_shards)
    shards = []
    with tempfile.TemporaryDirectory() as td:
        for i in range(n_shards):
            shards.append(dse.sweep_shard(
                spec, i, n_shards, cache_dir=os.path.join(td, f"s{i}"),
            ))
        merged = dse.merge_results(shards)
    bad = _same_result(merged, whole)
    assert not bad, f"shard merge diverged from single-host: {bad[:5]}"
    owned = sum(len([p for p in s.points if p is not None]) for s in shards)
    assert owned == len([p for p in whole.points if p is not None])
    return {
        "n_shards": n_shards,
        "loads": list(plan.loads),
        "merged_bit_identical": True,
    }


_CHILD_CODE = """
import sys
from benchmarks.sweep import build_spec
from benchmarks.paper_table1 import scaled
from repro import dse
scales = {k: max(v // 16, 16) for k, v in scaled(1).items()}
scales["fft"] = 64
print("child: starting", flush=True)
dse.sweep(build_spec(scales), cache_dir=sys.argv[1], workers=1)
print("child: done", flush=True)
"""


def check_kill_resume(spec, whole: dse.SweepResult) -> dict:
    """SIGKILL a child sweep mid-run, resume from its surviving cache,
    assert the resumed run only executes the missing unique runs and is
    bit-identical to the uninterrupted result."""
    with tempfile.TemporaryDirectory() as td:
        cache = os.path.join(td, "cache")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ("src", ".", env.get("PYTHONPATH", "")) if p
        )
        child = subprocess.Popen(
            [sys.executable, "-c", _CHILD_CODE, cache],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        )
        journal = os.path.join(cache, dse.SweepJournal.FILENAME)
        deadline = time.time() + 120.0
        lines = 0
        while time.time() < deadline and child.poll() is None:
            if os.path.exists(journal):
                with open(journal) as f:
                    lines = sum(1 for _ in f)
                if lines >= 2:
                    break
            time.sleep(0.05)
        finished_early = child.poll() is not None
        if not finished_early:
            child.send_signal(signal.SIGKILL)
        child.wait()

        res = dse.sweep(spec, cache_dir=cache, resume=True)
    st = res.stats
    assert st.n_cache_hits + st.n_executed == st.n_unique_runs
    if not finished_early:
        # the kill landed mid-run: the resume must have found surviving
        # work AND had something left to do
        assert st.n_resumed_runs >= 1, "resume found no surviving cache"
        assert st.n_executed >= 1, "child finished before the kill?"
    bad = _same_result(res, whole)
    assert not bad, f"kill+resume diverged from uninterrupted: {bad[:5]}"
    return {
        "journal_lines_at_kill": lines,
        "child_finished_early": finished_early,
        "resumed_runs": st.n_resumed_runs,
        "executed_after_resume": st.n_executed,
        "resume_bit_identical": True,
    }


def check_stream_pareto(spec) -> dict:
    """Drive the sweep through ``on_point`` feeding a ParetoTracker;
    assert every streaming prefix front equals the batch
    ``pareto_front`` recompute over the rows seen so far."""
    tracker = analysis.ParetoTracker()
    rows: list = []

    def on_point(pr):
        row = {
            "cycles": pr.result.cycles,
            "dram_bursts": pr.result.dram_bursts,
            "id": pr.point.point_id,
        }
        rows.append(row)
        tracker.update(row)
        batch = [rows[i] for i in analysis.pareto_front(rows)]
        assert tracker.front() == batch, (
            f"streaming front diverged at point {len(rows)}"
        )

    dse.sweep(spec, on_point=on_point)
    return {
        "n_points_streamed": len(rows),
        "final_front_size": len(tracker.front()),
        "every_prefix_matches_batch": True,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_DSE.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument(
        "--workers", type=int, default=0,
        help="parallel group workers for the headline run (0 = cpu count)",
    )
    ap.add_argument(
        "--skip-serial", action="store_true",
        help="skip the cold serial sweep measurement",
    )
    ap.add_argument(
        "--target-speedup", type=float, default=5.0,
        help="cold-sweep throughput bar to assert (the committed "
        "BENCH_DSE.json evidence uses the default 5.0 at --scale-mult "
        "8; CI canary runs at smaller scales assert a lower bar since "
        "shared-artifact amortization shrinks with scale)",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny scales, correctness-only (no speedup bar): CI gate. "
        "Also exercises shard+merge, kill+resume and streaming-Pareto "
        "service checks",
    )
    ap.add_argument(
        "--cache-dir", default=None,
        help="persistent result cache (default: fresh tempdir per phase)",
    )
    ap.add_argument(
        "--shard", default=None, metavar="I/N",
        help="run only shard I of an N-way partition (multi-host use; "
        "pair with --cache-dir, merge with dse.merge_results). Skips "
        "the baseline and speedup bars",
    )
    ap.add_argument(
        "--resume", action="store_true",
        help="re-plan from the surviving --cache-dir (missing runs only)",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="print each point as it lands + live partial Pareto front",
    )
    ap.add_argument(
        "--shard-check", type=int, default=0, metavar="N",
        help="after the headline run, redo the sweep as N shards and "
        "assert dse.merge_results equals the single-host result",
    )
    ap.add_argument(
        "--differential", action="store_true",
        help="per-point differential validation during the sweep",
    )
    a = ap.parse_args(argv)

    workers = a.workers or (os.cpu_count() or 1)
    if a.smoke:
        scales = {k: max(v // 16, 16) for k, v in scaled(1).items()}
        scales["fft"] = 64
    else:
        scales = scaled(a.scale_mult)
    spec = build_spec(scales)
    points = spec.points()
    print(f"sweep: {len(points)} points over {len(programs.TABLE1)} kernels "
          f"at scales {scales}", flush=True)

    tracker = analysis.ParetoTracker()

    def stream_cb(pr):
        row = {"cycles": pr.result.cycles,
               "dram_bursts": pr.result.dram_bursts}
        grew = tracker.update(row)
        print(f"point {pr.point.point_id}: cycles={pr.result.cycles} "
              f"cached={pr.cached} front={len(tracker.front())}"
              f"{' *' if grew else ''}", flush=True)

    on_point = stream_cb if a.stream else None

    # --- shard worker path: run the owned slice, write it, exit -----------
    if a.shard is not None:
        idx, n = (int(x) for x in a.shard.split("/"))
        t0 = time.perf_counter()
        if a.cache_dir:
            res = dse.sweep_shard(
                spec, idx, n, cache_dir=a.cache_dir, workers=workers,
                resume=a.resume, differential=a.differential,
                on_point=on_point,
            )
        else:
            with tempfile.TemporaryDirectory() as td:
                res = dse.sweep_shard(
                    spec, idx, n, cache_dir=td, workers=workers,
                    differential=a.differential, on_point=on_point,
                )
        wall = time.perf_counter() - t0
        st = res.stats
        data = {
            "shard": [idx, n], "wall_s": round(wall, 2),
            "n_points_owned": len([p for p in res.points if p is not None]),
            "n_unique_runs": st.n_unique_runs,
            "n_cache_hits": st.n_cache_hits,
            "n_executed": st.n_executed,
        }
        with open(a.out, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
        print(f"wrote {a.out}: shard {idx}/{n}, "
              f"{data['n_points_owned']} points in {wall:.1f}s")
        return data

    base_wall, base_sigs = run_baseline(points)
    print(f"baseline loop: {base_wall:.1f}s "
          f"({base_wall / len(points):.2f}s/point)", flush=True)

    walls = {}
    if not a.skip_serial:
        with tempfile.TemporaryDirectory() as td:
            t0 = time.perf_counter()
            res_serial = dse.sweep(spec, cache_dir=td, workers=1)
            walls["cold_serial_s"] = time.perf_counter() - t0
        print(f"dse cold serial: {walls['cold_serial_s']:.1f}s "
              f"({res_serial.n_unique_runs} unique runs)", flush=True)

    if a.cache_dir:
        td_ctx = None
        cache_dir = a.cache_dir
    else:
        td_ctx = tempfile.TemporaryDirectory()
        cache_dir = td_ctx.name
    try:
        t0 = time.perf_counter()
        res = dse.sweep(
            spec, cache_dir=cache_dir, workers=workers, profile=True,
            resume=a.resume, differential=a.differential,
            on_point=on_point,
        )
        walls["cold_parallel_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_warm = dse.sweep(spec, cache_dir=cache_dir, workers=1)
        walls["warm_s"] = time.perf_counter() - t0
    finally:
        if td_ctx is not None:
            td_ctx.cleanup()
    print(f"dse cold x{workers} workers: {walls['cold_parallel_s']:.1f}s; "
          f"warm: {walls['warm_s']:.1f}s "
          f"({res_warm.n_cache_hits}/{res_warm.n_unique_runs} hits)",
          flush=True)

    # --- bit-identity of every point vs its standalone call ---------------
    mismatches = []
    for pr in res.points:
        if _sig(pr.result) != base_sigs[pr.point.point_id]:
            mismatches.append(pr.point.point_id)
    identical = not mismatches
    print(f"bit-identity: {len(res.points) - len(mismatches)}/"
          f"{len(res.points)} points identical", flush=True)

    rows = res.rows()
    data = {
        "scale_mult": a.scale_mult if not a.smoke else 0,
        "smoke": a.smoke,
        "scales": scales,
        "n_points": len(points),
        "n_unique_runs": res.n_unique_runs,
        "workers": workers,
        "baseline_loop_s": round(base_wall, 2),
        **{k: round(v, 2) for k, v in walls.items()},
        "speedup_parallel": round(base_wall / walls["cold_parallel_s"], 2),
        "speedup_warm": round(base_wall / max(walls["warm_s"], 1e-9), 1),
        "target_speedup": a.target_speedup,
        "all_points_bit_identical": identical,
        "summary": analysis.summarize_sweep(rows),
        "forward_slack_profile": res.profile,
        "group_stats": res.groups,
    }
    if "cold_serial_s" in walls:
        data["speedup_serial"] = round(base_wall / walls["cold_serial_s"], 2)

    # --- sweep-service checks (DESIGN.md §13) ------------------------------
    n_shard_check = a.shard_check or (2 if a.smoke else 0)
    if n_shard_check:
        data["shard_check"] = check_shard_merge(spec, res, n_shard_check)
        print(f"shard check: {n_shard_check}-way merge bit-identical "
              f"(loads {data['shard_check']['loads']})", flush=True)
    if a.smoke:
        data["kill_resume"] = check_kill_resume(spec, res)
        print(f"kill+resume: killed at "
              f"{data['kill_resume']['journal_lines_at_kill']} journal "
              f"lines, resumed {data['kill_resume']['resumed_runs']} runs, "
              f"bit-identical", flush=True)
        data["stream_pareto"] = check_stream_pareto(spec)
        print(f"streaming pareto: {data['stream_pareto']['n_points_streamed']}"
              f" points, every prefix front matches batch recompute",
              flush=True)

    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)

    assert identical, f"sweep diverged from standalone simulate(): {mismatches[:5]}"
    if not a.smoke:
        assert data["speedup_parallel"] >= data["target_speedup"], (
            f"sweep throughput regressed: {data['speedup_parallel']}x "
            f"< target {data['target_speedup']}x vs the looped baseline"
        )
    print(
        f"wrote {a.out}: {data['speedup_parallel']}x cold "
        f"(serial {data.get('speedup_serial', '-')}x, warm "
        f"{data['speedup_warm']}x) vs looped simulate(); "
        f"bit-identical={identical}"
    )
    return data


if __name__ == "__main__":
    main()
