"""Engine benchmark: cycle vs event engine wall-clock and conformance.

Produces the evidence file committed as ``BENCH_ENGINE.json``:

  * per Table-1 kernel, FUS2 (and LSQ at 1x) wall-clock of both engines
    at the paper_table1 scales, plus the event engine alone at
    ``--scale-mult`` (default 8x — the cycle engine is too slow there,
    which is the point),
  * cycle-count drift between engines (conformance contract: <= 2%,
    see DESIGN.md §1.2),
  * the tier-1 suite wall-clock, if provided via --tier1-seconds.

Usage:
    PYTHONPATH=src python benchmarks/bench_engine.py \
        --out BENCH_ENGINE.json --tier1-seconds 36.4 --tier1-seed-seconds 164
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core import programs, simulator
from benchmarks.paper_table1 import SCALES, scaled


def _run(prog, arrays, params, mode, engine):
    t0 = time.time()
    res = simulator.simulate(prog, arrays, params, mode=mode, engine=engine)
    return time.time() - t0, res


def bench(scale_mult: int = 8, modes=("LSQ", "FUS2")) -> dict:
    out = {
        "scales_1x": dict(SCALES),
        "scale_mult": scale_mult,
        "kernels": {},
    }
    for name in programs.TABLE1:
        row: dict = {}
        prog, arrays, params = programs.get(name).make(SCALES[name])
        for mode in modes:
            t_cy, r_cy = _run(prog, arrays, params, mode, "cycle")
            t_ev, r_ev = _run(prog, arrays, params, mode, "event")
            drift = abs(r_ev.cycles - r_cy.cycles) / max(r_cy.cycles, 1)
            row[mode] = {
                "cycles_cycle": r_cy.cycles,
                "cycles_event": r_ev.cycles,
                "cycle_drift": round(drift, 6),
                "wall_cycle_s": round(t_cy, 3),
                "wall_event_s": round(t_ev, 3),
                "speedup": round(t_cy / max(t_ev, 1e-9), 2),
            }
        big = scaled(scale_mult)[name]
        prog, arrays, params = programs.get(name).make(big)
        t_ev, r_ev = _run(prog, arrays, params, "FUS2", "event")
        row["FUS2_at_mult"] = {
            "scale": big,
            "wall_event_s": round(t_ev, 3),
            "cycles": r_ev.cycles,
            "requests": r_ev.dram_requests,
        }
        out["kernels"][name] = row
        top = row[modes[-1]]
        print(f"{name:10s} done: 1x {modes[-1]} {top['wall_cycle_s']}s cycle "
              f"-> {top['wall_event_s']}s event; "
              f"{scale_mult}x event {t_ev:.2f}s", flush=True)
    drifts = [
        row[m]["cycle_drift"]
        for row in out["kernels"].values()
        for m in modes
    ]
    out["max_cycle_drift"] = max(drifts)
    out["conformance_tolerance"] = 0.02
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ENGINE.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--tier1-seconds", type=float, default=None)
    ap.add_argument("--tier1-seed-seconds", type=float, default=None)
    a = ap.parse_args()
    data = bench(scale_mult=a.scale_mult)
    if a.tier1_seconds is not None:
        data["tier1_wall_s"] = a.tier1_seconds
    if a.tier1_seed_seconds is not None:
        data["tier1_seed_wall_s"] = a.tier1_seed_seconds
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"wrote {a.out}: max drift {data['max_cycle_drift']:.4%}")


if __name__ == "__main__":
    main()
