"""Engine benchmark: cycle vs event engine wall-clock and conformance.

Produces the evidence file committed as ``BENCH_ENGINE.json``:

  * per Table-1 kernel, FUS2 (and LSQ at 1x) wall-clock of both engines
    at the paper_table1 scales, plus the event engine alone at
    ``--scale-mult`` (default 8x — the cycle engine is too slow there,
    which is the point),
  * cycle-count drift between engines (conformance contract: <= 2%,
    see DESIGN.md §1.2),
  * the tier-1 suite wall-clock, if provided via --tier1-seconds.

Usage:
    PYTHONPATH=src python benchmarks/bench_engine.py \
        --out BENCH_ENGINE.json --tier1-seconds 36.4 --tier1-seed-seconds 164
"""

from __future__ import annotations

import argparse
import json
import time

from repro.core import programs, simulator
from benchmarks.paper_table1 import SCALES, scaled


def _run(prog, arrays, params, mode, engine, trace_mode="auto"):
    t0 = time.time()
    res = simulator.simulate(
        prog, arrays, params, mode=mode, engine=engine, trace_mode=trace_mode
    )
    return time.time() - t0, res


def smoke(trace_modes=("interp", "compiled")) -> None:
    """Tier-1 CI smoke: every Table-1 kernel, event engine, FUS2, run
    once per trace mode. Asserts the trace-mode contract: identical
    final arrays AND identical cycle counts (the engine consumes equal
    streams either way)."""
    import numpy as np

    for name in programs.TABLE1:
        prog, arrays, params = programs.get(name).make(SCALES[name])
        results = {}
        for tm in trace_modes:
            results[tm] = _run(prog, arrays, params, "FUS2", "event", tm)
        (t0, r0), (t1, r1) = results[trace_modes[0]], results[trace_modes[1]]
        assert r0.cycles == r1.cycles, (
            f"{name}: cycles diverged across trace modes "
            f"({trace_modes[0]}={r0.cycles}, {trace_modes[1]}={r1.cycles})"
        )
        for k in r0.arrays:
            np.testing.assert_array_equal(
                r0.arrays[k], r1.arrays[k],
                err_msg=f"{name}: arrays diverged across trace modes ({k})",
            )
        print(
            f"{name:10s} smoke OK: cycles={r0.cycles} "
            + " ".join(f"{tm}={results[tm][0]:.3f}s" for tm in trace_modes),
            flush=True,
        )
    print(f"smoke OK: {len(programs.TABLE1)} kernels x {trace_modes}")


def bench(scale_mult: int = 8, modes=("LSQ", "FUS2"), trace_mode="auto") -> dict:
    out = {
        "scales_1x": dict(SCALES),
        "scale_mult": scale_mult,
        "trace_mode": trace_mode,
        "kernels": {},
    }
    for name in programs.TABLE1:
        row: dict = {}
        prog, arrays, params = programs.get(name).make(SCALES[name])
        for mode in modes:
            t_cy, r_cy = _run(prog, arrays, params, mode, "cycle", trace_mode)
            t_ev, r_ev = _run(prog, arrays, params, mode, "event", trace_mode)
            drift = abs(r_ev.cycles - r_cy.cycles) / max(r_cy.cycles, 1)
            row[mode] = {
                "cycles_cycle": r_cy.cycles,
                "cycles_event": r_ev.cycles,
                "cycle_drift": round(drift, 6),
                "wall_cycle_s": round(t_cy, 3),
                "wall_event_s": round(t_ev, 3),
                "speedup": round(t_cy / max(t_ev, 1e-9), 2),
            }
        big = scaled(scale_mult)[name]
        prog, arrays, params = programs.get(name).make(big)
        t_ev, r_ev = _run(prog, arrays, params, "FUS2", "event", trace_mode)
        row["FUS2_at_mult"] = {
            "scale": big,
            "wall_event_s": round(t_ev, 3),
            "cycles": r_ev.cycles,
            "requests": r_ev.dram_requests,
        }
        out["kernels"][name] = row
        top = row[modes[-1]]
        print(f"{name:10s} done: 1x {modes[-1]} {top['wall_cycle_s']}s cycle "
              f"-> {top['wall_event_s']}s event; "
              f"{scale_mult}x event {t_ev:.2f}s", flush=True)
    drifts = [
        row[m]["cycle_drift"]
        for row in out["kernels"].values()
        for m in modes
    ]
    out["max_cycle_drift"] = max(drifts)
    out["conformance_tolerance"] = 0.02
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ENGINE.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--tier1-seconds", type=float, default=None)
    ap.add_argument("--tier1-seed-seconds", type=float, default=None)
    ap.add_argument(
        "--trace-mode", choices=("auto", "compiled", "interp"), default="auto",
        help="AGU/CU front-end path for the benchmarked runs",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="tier-1 CI smoke: Table 1 at 1x, event engine, both trace "
        "modes, conformance-asserted (no JSON output)",
    )
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    data = bench(scale_mult=a.scale_mult, trace_mode=a.trace_mode)
    if a.tier1_seconds is not None:
        data["tier1_wall_s"] = a.tier1_seconds
    if a.tier1_seed_seconds is not None:
        data["tier1_seed_wall_s"] = a.tier1_seed_seconds
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"wrote {a.out}: max drift {data['max_cycle_drift']:.4%}")


if __name__ == "__main__":
    main()
