"""Pallas wave-backend benchmark: fused wave-parallel execution of every
Table-1 kernel (plus the four speculative kernels) vs the sequential
per-request path on the same hardware route.

Produces the evidence file committed as ``BENCH_PALLAS.json``:

  * per kernel at ``--scale-mult`` x the paper_table1 scales: request
    count, wave count, wave parallelism (requests / waves — the Fig. 1c
    cross-loop parallelism the paper's DU extracts by stalling and the
    wave backend extracts by partitioning), batched-step count and
    parallelism, measured wall-clock of the Pallas wave path, and the
    sequential one-request-per-step baseline. The baseline is measured
    over a ``--seq-steps`` prefix: ``seq_measured_wall_s`` /
    ``seq_steps_measured`` are always what the clock actually saw, and
    ``seq_extrapolated`` says which speedup key is present —
    ``speedup_vs_sequential`` only when the baseline ran to completion,
    ``speedup_vs_sequential_extrapolated`` (against
    ``seq_wall_s_extrapolated``) otherwise. Measured and extrapolated
    numbers never share a key,
  * bit-exactness: final arrays of the wave backend are asserted
    array-equal against ``simulate()`` (FUS2, event engine) AND the
    sequential oracle for every kernel,
  * frontier cross-checks: for the monotonic producer/consumer shapes
    (the three microbenchmarks and tanh+spmv's §6-guarded producer),
    per-request waves / forwarded values are *independently*
    reconstructed through the generalized ``kernels/du_hazard`` /
    ``kernels/fused_stream`` Pallas kernels and matched against the
    WavePlan.

``--smoke`` is the tier-1 CI gate: all nine Table-1 kernels (and the
speculative three) at reduced scales through the real Pallas path
(interpret mode), both trace modes, oracle-asserted, no JSON.

Usage:
    PYTHONPATH=src:. python benchmarks/bench_pallas.py \
        --scale-mult 8 --out BENCH_PALLAS.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import executor, loopir as ir, programs, simulator
from repro.kernels import wave_exec
from benchmarks.paper_table1 import SCALES, scaled

# tier-1 smoke scales: small enough that 12 kernels x 2 trace modes of
# interpret-mode Pallas fit the tier-1 wall-clock budget
SMOKE_SCALES = {
    "RAWloop": 256, "WARloop": 256, "WAWloop": 256,
    "bnn": 16, "pagerank": 24, "fft": 64, "matpower": 16,
    "hist+add": 256, "tanh+spmv": 96,
    "spmv_ldtrip": 32, "bfs_front": 64, "chase_sum": 48,
    "strided_scan": 48,
}

# wave-parallelism bar asserted on the full run: every Table-1 kernel
# must extract real cross-loop parallelism
PAR_BAR = 1.5
# the three kernels the old per-PE-barrier partition serialized (their
# stores waited on *every* prior load of their PE, not just the feeding
# ones): the exact per-(PE, dep-edge) partition must keep them well
# clear of that floor
PAR_FLOORS = {"matpower": 8.0, "pagerank": 8.0, "spmv_ldtrip": 8.0}
# wall-clock bar: interpret-mode step overhead dominates both paths, so
# the wave path's win tracks its step-count reduction — demand a real
# speedup only where the partition removes most steps (parallelism >=
# SPEEDUP_PAR_MIN); near the structural floor demand it not be
# pathologically slower than one-request-per-step
SPEEDUP_PAR_MIN = 4.0
SPEEDUP_FLOOR = 0.5


def _op_stream(plan, op_id):
    """(addr, valid, value, wave) of one op, in program order."""
    rows = np.nonzero(plan.req_op == plan.op_ids.index(op_id))[0]
    return (plan.req_addr[rows], plan.req_valid[rows],
            plan.req_value[rows], plan.req_wave[rows])


def frontier_crosschecks(name, plan, arrays, interpret=True):
    """Independent Pallas-path reconstruction for monotonic shapes.

    Returns the list of checks performed (empty for kernels whose
    producer streams are not globally monotonic — bnn's per-row-sorted
    scatter, the CSR kernels).
    """
    from repro.kernels.du_hazard.ops import (
        hazard_frontier, wave_partition,
    )
    from repro.kernels.fused_stream.ops import fused_stream, min_lookback

    done = []
    pairs = {
        # (producer op, consumer op, hazard side): "right" counts the
        # equal-address producer — the WAR store *waits for* the load of
        # its own address, so all three directions merge side="right"
        "RAWloop": ("st_a", "ld_a", "right"),
        "WARloop": ("ld_a", "st_a", "right"),
        "WAWloop": ("st_0", "st_1", "right"),
    }
    if name in pairs:
        src_id, dst_id, side = pairs[name]
        src_addr, _, _, src_wave = _op_stream(plan, src_id)
        dst_addr, _, _, dst_wave = _op_stream(plan, dst_id)
        f = hazard_frontier(
            jnp.asarray(src_addr), jnp.asarray(dst_addr), side=side,
            interpret=interpret,
        )
        got = wave_partition(f, jnp.asarray(src_wave))
        np.testing.assert_array_equal(
            np.asarray(got), dst_wave,
            err_msg=f"{name}: Pallas frontier waves != WavePlan ({dst_id})",
        )
        done.append(f"wave_partition[{side}]({src_id}->{dst_id})")
    if name == "tanh+spmv":
        # §6-guarded producer (st_v) forwarding into the SpMV's value
        # gather (ld_vv): generalized fused_stream with valid bits
        src_addr, src_valid, src_value, _ = _op_stream(plan, "st_v")
        dst_addr, _, dst_value, _ = _op_stream(plan, "ld_vv")
        lb = min_lookback(src_addr)
        f = hazard_frontier(
            jnp.asarray(src_addr), jnp.asarray(dst_addr),
            interpret=interpret,
        )
        vals, hits = fused_stream(
            jnp.asarray(src_addr),
            jnp.asarray(np.where(src_valid, src_value, 0.0)),
            f, jnp.asarray(dst_addr),
            jnp.asarray(arrays["v"]),
            jnp.asarray(src_valid.astype(np.int32)),
            lookback=lb, interpret=interpret,
        )
        np.testing.assert_allclose(
            np.asarray(vals), dst_value, atol=1e-12,
            err_msg="tanh+spmv: guarded forwarding != oracle ld_vv",
        )
        assert bool(np.asarray(hits).any()), "no forwards — shape degenerate"
        done.append(f"fused_stream[valid,lb={lb}](st_v->ld_vv)")
    return done


def run_kernel(name, scale, *, trace_mode="auto", check=True,
               seq_steps=0):
    """One kernel through the Pallas wave backend; returns (row, plan)."""
    bench = programs.get(name)
    prog, arrays, params = bench.make(scale)
    spec = "auto" if bench.speculative else "off"
    oracle = ir.interpret(prog, arrays, params)

    t0 = time.time()
    plan = executor.build_wave_plan(
        prog, arrays, params, trace_mode=trace_mode, speculation=spec,
    )
    t_plan = time.time() - t0

    t0 = time.time()
    res = wave_exec.run_plan(plan, arrays, interpret=True, check=check)
    t_wave = time.time() - t0

    for k in oracle:
        np.testing.assert_array_equal(
            res.arrays[k], oracle[k],
            err_msg=f"{name}: wave backend diverged from oracle ({k})",
        )
    sim = simulator.simulate(prog, arrays, params, mode="FUS2",
                             engine="event", speculation=spec)
    for k in sim.arrays:
        np.testing.assert_array_equal(
            res.arrays[k], sim.arrays[k],
            err_msg=f"{name}: wave backend diverged from simulate() ({k})",
        )

    row = {
        "scale": scale,
        "speculative": bench.speculative,
        "trace_mode": trace_mode,
        "n_requests": plan.stats.n_requests,
        "n_waves": plan.stats.n_waves,
        "n_steps": plan.stats.n_steps,
        "parallelism": round(plan.stats.parallelism, 2),
        "step_parallelism": round(plan.stats.step_parallelism, 2),
        "plan_wall_s": round(t_plan, 3),
        "wave_wall_s": round(t_wave, 3),
        "wave_resolve_s": round(res.resolve_s, 3),
        "wave_device_s": round(res.device_s, 3),
        "pallas_steps": res.n_steps,
        "pallas_segments": res.n_segments,
    }
    if seq_steps:
        limit = min(seq_steps, plan.stats.n_requests)
        seq = wave_exec.run_sequential(
            plan, arrays, interpret=True, check=False, max_steps=limit,
        )
        # measured and extrapolated numbers never share a key: the
        # measured wall/steps are always reported as such, and only a
        # complete baseline may claim the unqualified speedup
        row["seq_extrapolated"] = not seq.complete
        row["seq_steps_measured"] = seq.n_steps
        row["seq_measured_wall_s"] = round(seq.elapsed, 3)
        if seq.complete:
            row["seq_wall_s"] = round(seq.elapsed, 3)
            row["speedup_vs_sequential"] = round(
                seq.elapsed / max(t_wave, 1e-9), 2
            )
        else:
            per_step = seq.elapsed / max(seq.n_steps, 1)
            est = per_step * plan.stats.n_requests
            row["seq_wall_s_extrapolated"] = round(est, 3)
            row["speedup_vs_sequential_extrapolated"] = round(
                est / max(t_wave, 1e-9), 2
            )
    return row, plan, arrays


def smoke():
    """Tier-1 CI smoke: every Table-1 + speculative kernel through the
    Pallas wave backend at SMOKE_SCALES, oracle-asserted; Table-1 also
    runs the compiled trace mode and pins identical waves."""
    for name in programs.TABLE1:
        row, plan, arrays = run_kernel(name, SMOKE_SCALES[name],
                                       trace_mode="interp")
        row_c, plan_c, _ = run_kernel(name, SMOKE_SCALES[name],
                                      trace_mode="compiled")
        np.testing.assert_array_equal(
            plan.req_wave, plan_c.req_wave,
            err_msg=f"{name}: waves diverged across trace modes",
        )
        checks = frontier_crosschecks(name, plan, arrays)
        print(f"{name:12s} smoke OK: waves={row['n_waves']} "
              f"par={row['parallelism']}x"
              + (f" [{', '.join(checks)}]" if checks else ""), flush=True)
    for name in programs.SPEC_KERNELS:
        row, _, _ = run_kernel(name, SMOKE_SCALES[name], trace_mode="auto")
        print(f"{name:12s} smoke OK: waves={row['n_waves']} "
              f"par={row['parallelism']}x (speculative)", flush=True)
    n = len(programs.TABLE1) + len(programs.SPEC_KERNELS)
    print(f"smoke OK: {n} kernels through the Pallas wave backend")


def bench(scale_mult: int = 8, seq_steps: int = 256) -> dict:
    out: dict = {"scale_mult": scale_mult, "seq_steps": seq_steps,
                 "scales_1x": dict(SCALES), "kernels": {}}
    scales = scaled(scale_mult)
    for name in programs.TABLE1:
        row, plan, arrays = run_kernel(
            name, scales[name], check=False, seq_steps=seq_steps,
        )
        row["crosschecks"] = frontier_crosschecks(name, plan, arrays)
        out["kernels"][name] = row
        if "seq_wall_s" in row:
            seq = f" vs seq {row['seq_wall_s']}s"
        elif "seq_wall_s_extrapolated" in row:
            seq = f" vs seq ~{row['seq_wall_s_extrapolated']}s (extrap)"
        else:
            seq = ""
        print(f"{name:12s} @{row['scale']}: {row['n_requests']} req in "
              f"{row['n_waves']} waves ({row['parallelism']}x), wave "
              f"{row['wave_wall_s']}s{seq}", flush=True)
    for name in programs.SPEC_KERNELS:
        scale = programs.get(name).default_scale * scale_mult
        row, plan, arrays = run_kernel(
            name, scale, check=False, seq_steps=seq_steps,
        )
        out["kernels"][name] = row
        print(f"{name:12s} @{scale}: {row['n_requests']} req in "
              f"{row['n_waves']} waves ({row['parallelism']}x)", flush=True)
    return out


def check_bar(data: dict) -> None:
    for name in programs.TABLE1:
        row = data["kernels"][name]
        assert row["parallelism"] >= PAR_BAR, (
            f"{name}: wave parallelism {row['parallelism']} below the "
            f"{PAR_BAR}x bar"
        )
        # absent when run with --seq-steps 0 (no baseline measured);
        # the extrapolated speedup (if that is what we have) holds to
        # the same bar — it is overhead-dominated in interpret mode, so
        # extrapolation is linear in step count
        speedup = row.get("speedup_vs_sequential",
                          row.get("speedup_vs_sequential_extrapolated"))
        if speedup is None:
            continue
        bar = 1.0 if row["parallelism"] >= SPEEDUP_PAR_MIN else SPEEDUP_FLOOR
        assert speedup > bar, (
            f"{name}: wave wall-clock speedup {speedup} below the "
            f"{bar}x bar (parallelism {row['parallelism']})"
        )
    # the old per-PE barrier serialized these three; the exact
    # per-(PE, dep-edge) partition must hold them above the floor
    # (spmv_ldtrip is a SPEC_KERNELS row, hence the second loop's data)
    for name, floor in PAR_FLOORS.items():
        row = data["kernels"][name]
        assert row["parallelism"] >= floor, (
            f"{name}: wave parallelism {row['parallelism']} below the "
            f"{floor}x serialization floor"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_PALLAS.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--seq-steps", type=int, default=256,
                    help="sequential-baseline steps measured before "
                    "extrapolating")
    ap.add_argument("--no-assert", action="store_true")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tier-1 CI smoke: reduced scales, oracle-asserted, no JSON",
    )
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    data = bench(scale_mult=a.scale_mult, seq_steps=a.seq_steps)
    if not a.no_assert:
        check_bar(data)
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    pars = {k: v["parallelism"] for k, v in data["kernels"].items()}
    print(f"wrote {a.out}: wave parallelism {pars}")


if __name__ == "__main__":
    main()
