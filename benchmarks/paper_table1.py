"""Paper Table 1 reproduction: STA / LSQ / FUS1 / FUS2 on the nine
irregular kernels, as simulated cycles + speedups.

Absolute FPGA wall-clock is not reproducible off-chip; the deliverable
is the *structure* of Table 1 — which approach wins where, and by
roughly how much — under the documented DU timing model
(core/simulator.SimParams). The paper's headline: FUS2 ≈ 14x over STA
and ≈ 4x over LSQ (harmonic means; dominated by bnn/hist-style codes).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import loopir, programs, simulator

MODES = ("STA", "LSQ", "FUS1", "FUS2")

# benchmark scales sized so the full table runs in ~a minute on CPU with
# the cycle engine; the event engine (default) runs these much faster
# and supports --scale-mult well beyond 8x (see BENCH_ENGINE.json)
SCALES = {
    "RAWloop": 2048, "WARloop": 2048, "WAWloop": 2048,
    "bnn": 64, "pagerank": 96, "fft": 256, "matpower": 64,
    "hist+add": 1024, "tanh+spmv": 256,
}


def scaled(mult: int) -> dict[str, int]:
    """SCALES at an integer multiple (fft stays a power of two)."""
    if mult < 1:
        raise ValueError(f"--scale-mult must be >= 1, got {mult}")
    out = {}
    for k, v in SCALES.items():
        s = v * mult
        if k == "fft":
            s = 1 << (s.bit_length() - 1)
        out[k] = s
    return out


def run_table(scales=None, validate=False, engine="event", trace_mode="auto"):
    scales = scales or SCALES
    rows = []
    for name in programs.TABLE1:
        prog, arrays, params = programs.get(name).make(scales[name])
        oracle = loopir.interpret(prog, arrays, params)
        row = {"kernel": name}
        for mode in MODES:
            t0 = time.time()
            res = simulator.simulate(
                prog, arrays, params, mode=mode,
                validate=validate and mode != "STA", engine=engine,
                trace_mode=trace_mode,
            )
            for k in oracle:
                assert np.allclose(res.arrays[k], oracle[k], atol=1e-9), (
                    name, mode, k,
                )
            row[mode] = res.cycles
            row[f"{mode}_wall_s"] = time.time() - t0
            if mode == "FUS2":
                row["forwards"] = res.forwards
        n_pes = len(simulator.Compiled(prog, False).dae.pes)
        row["PEs"] = n_pes
        rows.append(row)
    return rows


# single implementation lives in the importable library layer
from repro.launch.analysis import harmonic_mean  # noqa: E402


def summarize(rows):
    out = {}
    for base in ("STA", "LSQ"):
        speedups = [r[base] / r["FUS2"] for r in rows]
        out[f"FUS2_vs_{base}_hmean"] = harmonic_mean(speedups)
        out[f"FUS2_vs_{base}_max"] = max(speedups)
    out["FUS2_vs_FUS1_hmean"] = harmonic_mean(
        [r["FUS1"] / r["FUS2"] for r in rows]
    )
    return out


def main(csv=True, scale_mult=1, engine="event", trace_mode="auto"):
    rows = run_table(
        scales=scaled(scale_mult), engine=engine, trace_mode=trace_mode
    )
    if csv:
        print("kernel,PEs,STA,LSQ,FUS1,FUS2,fus2_vs_sta,fus2_vs_lsq,forwards")
        for r in rows:
            print(
                f"{r['kernel']},{r['PEs']},{r['STA']},{r['LSQ']},{r['FUS1']},"
                f"{r['FUS2']},{r['STA']/r['FUS2']:.2f},"
                f"{r['LSQ']/r['FUS2']:.2f},{r['forwards']}"
            )
        s = summarize(rows)
        print(
            f"hmean,,,,,,{s['FUS2_vs_STA_hmean']:.2f},"
            f"{s['FUS2_vs_LSQ_hmean']:.2f},"
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale-mult", type=int, default=1,
                    help="run Table 1 at N x the default scales")
    ap.add_argument("--engine", choices=("cycle", "event"), default="event")
    ap.add_argument(
        "--trace-mode", choices=("auto", "compiled", "interp"), default="auto",
        help="AGU/CU front-end: compiled (vectorized), interp (reference), "
        "or auto (compile where exact, fall back per PE)",
    )
    a = ap.parse_args()
    main(scale_mult=a.scale_mult, engine=a.engine, trace_mode=a.trace_mode)
