"""Sweep-driven SimParams calibration evidence (``BENCH_CALIB.json``).

Runs ``dse.calibrate()`` — the two-stage grid fit of ``sta_mem_dep_ii``
(STA stage) and ``dram_latency`` x ``forward_latency`` (FUS2 stage)
against the paper's Table-1 per-iteration cycle targets — and writes
the committed calibration evidence:

  * the fitted SimParams fields (the values baked into
    ``simulator.SimParams`` defaults; the assert at the end keeps the
    committed defaults and the fit from drifting apart),
  * per-kernel measured vs target cycles/iteration and relative error,
  * the full per-field fit curves (mean relative error at every grid
    value), so a reader can see which fields the targets actually
    identify (``forward_latency``'s curve is flat — the
    identifiability rule keeps its default).

Usage:
    PYTHONPATH=src:. python benchmarks/bench_calibrate.py \
        --out BENCH_CALIB.json --scale-div 2 --workers 4
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro import dse
from repro.core.simulator import SimParams
from repro.dse.calibrate import FUS2_TARGETS_CPI, STA_TARGETS_CPI


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_CALIB.json")
    ap.add_argument(
        "--scale-div", type=int, default=2,
        help="per-kernel scale = default_scale // scale-div (smaller "
        "div = larger problems = steadier cycles/iter)",
    )
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument(
        "--smoke", action="store_true",
        help="coarse grids + small scales; checks the fit machinery, "
        "not the committed values",
    )
    a = ap.parse_args(argv)

    t0 = time.perf_counter()
    if a.smoke:
        calib = dse.calibrate(
            scale_div=16,
            sta_grid=(128, 224),
            dram_grid=(200, 400),
            fwd_grid=(1,),
            workers=a.workers,
        )
    else:
        calib = dse.calibrate(scale_div=a.scale_div, workers=a.workers)
    wall = time.perf_counter() - t0

    defaults = SimParams()
    committed = {
        f: getattr(defaults, f) for f in calib.fitted
    }
    data = {
        "smoke": a.smoke,
        "wall_s": round(wall, 2),
        "scales": calib.scales,
        "iters_per_kernel": calib.iters,
        "fitted": calib.fitted,
        "committed_defaults": committed,
        "mean_rel_err": calib.mean_rel_err,
        "per_kernel": calib.per_kernel,
        "fit_curves": calib.per_field,
        "targets": {
            "STA_cpi": dict(STA_TARGETS_CPI),
            "FUS2_cpi": dict(FUS2_TARGETS_CPI),
        },
    }
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)

    for k, per in calib.per_kernel.items():
        for stage, d in per.items():
            print(f"{k:>10} {stage}: target {d['target_cpi']:7.1f} "
                  f"fitted {d['fitted_cpi']:7.1f} cyc/iter "
                  f"(rel err {d['rel_err']:.2%})")
    print(f"fitted: {calib.fitted} (mean rel err "
          f"{calib.mean_rel_err:.2%}, {wall:.1f}s)")

    if not a.smoke:
        # the committed SimParams defaults must BE the fit — a drift
        # here means someone changed the model without re-calibrating
        assert calib.fitted == committed, (
            f"SimParams defaults {committed} drifted from the "
            f"calibration fit {calib.fitted}: re-run this benchmark "
            f"and update simulator.SimParams"
        )
        assert calib.mean_rel_err <= 0.10, (
            f"calibration fit degraded: mean relative error "
            f"{calib.mean_rel_err:.2%} > 10%"
        )
    assert dataclasses.replace(SimParams(), **calib.fitted) == calib.params
    print(f"wrote {a.out}: defaults match fit, "
          f"mean rel err {calib.mean_rel_err:.2%}")
    return data


if __name__ == "__main__":
    main()
