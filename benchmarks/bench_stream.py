"""Streaming-kernel benchmark: cross-PE FIFO dataflow programs
(core/fifo.py, DESIGN.md §11) across both simulator engines and both
wave backends, swept over the ``fifo_depth`` axis.

Produces the evidence file committed as ``BENCH_STREAM.json``:

  * per streaming kernel (``stream_dot``, ``filter_pipe``,
    ``stream_join``) at ``--scale-mult`` x the registry default scales:
    event-engine cycle counts and per-edge queue accounting (pushed /
    popped / max occupancy / push+pop stalls) at each swept depth — the
    backpressure evidence: depth 1 pins ``max_occupancy == 1`` and
    serializes the wave plan hardest, deeper queues relax the slot
    WAW/WAR chains into fewer, wider waves,
  * wave-plan stats (requests, waves, steps, parallelism, streamed
    token counts) per depth, ``executor.validate_plan``-checked,
  * bit-exactness everywhere: every engine / backend / depth result is
    asserted array-equal against the hand-written numpy oracles
    (kernels/dynloop/ref.py) — never against each other only,
  * the Pallas wave path (interpret mode) wall-clock at the default
    depth, with the run_sequential one-request-per-step baseline over a
    ``--seq-steps`` prefix (measured and extrapolated numbers never
    share a key, same convention as bench_pallas.py).

``--smoke`` is the tier-1 CI gate: all three kernels at reduced scales
through BOTH engines (cycle + event, cycle counts asserted equal), the
numpy executor and the real Pallas path at depths 1 and 4,
oracle-asserted, no JSON.

Usage:
    PYTHONPATH=src:. python benchmarks/bench_stream.py \
        --scale-mult 8 --out BENCH_STREAM.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import executor, loopir as ir, programs, simulator
from repro.kernels import wave_exec
from repro.kernels.dynloop import ref

# tier-1 smoke scales: small enough for the cycle engine and
# interpret-mode Pallas at two depths inside the tier-1 budget
SMOKE_SCALES = {"stream_dot": 12, "filter_pipe": 48, "stream_join": 32}
DEPTHS = (1, 2, 4)
DEFAULT_DEPTH = 4
# wave-parallelism bar at the default depth: the slot encoding must
# leave real cross-instance parallelism on the table
PAR_BAR = 1.5


def _copies(arrays):
    return {k: v.copy() for k, v in arrays.items()}


def _oracle(name, arrays, params):
    """The hand-written second semantics (kernels/dynloop/ref.py)."""
    if name == "stream_dot":
        return {
            "out": ref.stream_dot_ref(
                arrays["a"], arrays["bv"], arrays["out"],
                params["nb"], params["k"],
            )
        }
    if name == "filter_pipe":
        return {"y": ref.filter_pipe_ref(arrays["x"], arrays["y"])}
    assert name == "stream_join"
    return {"z": ref.stream_join_ref(arrays["u"], arrays["w"], arrays["z"])}


def _assert_oracle(name, label, got, oracle):
    for k, v in oracle.items():
        np.testing.assert_array_equal(
            got[k], v, err_msg=f"{name}: {label} diverged from oracle ({k})"
        )


def run_kernel(name, scale, *, engines=("event",), depths=DEPTHS,
               pallas_depths=(DEFAULT_DEPTH,), seq_steps=0):
    """One streaming kernel through engines + backends + depth sweep."""
    bench = programs.get(name)
    prog, arrays, params = bench.make(scale)
    oracle = _oracle(name, arrays, params)
    _assert_oracle(
        name, "interpret",
        ir.interpret(prog, _copies(arrays), params), oracle,
    )

    row = {"scale": scale, "engines": {}, "depths": {}}
    cycles_seen = {}
    for engine in engines:
        res = simulator.simulate(
            prog, _copies(arrays), params, mode="FUS2", engine=engine
        )
        _assert_oracle(name, f"{engine} engine", res.arrays, oracle)
        row["engines"][engine] = {
            "cycles": res.cycles, "fifo": res.fifo_stats,
        }
        cycles_seen[engine] = res.cycles
    if len(cycles_seen) > 1:
        assert len(set(cycles_seen.values())) == 1, (
            f"{name}: engine cycle counts diverged: {cycles_seen}"
        )

    for depth in depths:
        res_t = simulator.simulate(
            prog, _copies(arrays), params, mode="FUS2", engine="event",
            sim=simulator.SimParams(fifo_depth=depth),
        )
        _assert_oracle(name, f"event@depth={depth}", res_t.arrays, oracle)
        t0 = time.time()
        plan = executor.build_wave_plan(
            prog, _copies(arrays), params, fifo_depth=depth
        )
        t_plan = time.time() - t0
        executor.validate_plan(plan)
        res_np = executor.execute(
            prog, _copies(arrays), params, fifo_depth=depth
        )
        _assert_oracle(name, f"numpy@depth={depth}", res_np.arrays, oracle)
        d = {
            "cycles": res_t.cycles,
            "fifo": res_t.fifo_stats,
            "n_requests": plan.stats.n_requests,
            "n_waves": plan.stats.n_waves,
            "n_steps": plan.stats.n_steps,
            "parallelism": round(plan.stats.parallelism, 2),
            "n_tokens": sum(fe["n_tokens"] for fe in plan.fifo_edges),
            "plan_wall_s": round(t_plan, 3),
        }
        if depth == 1:
            for qs in res_t.fifo_stats:
                assert qs["max_occupancy"] == 1, (
                    f"{name}: depth-1 queue overfilled: {qs}"
                )
        if depth in pallas_depths:
            t0 = time.time()
            res_pl = wave_exec.run_plan(plan, arrays, interpret=True)
            t_wave = time.time() - t0
            assert res_pl.complete
            _assert_oracle(
                name, f"pallas@depth={depth}", res_pl.arrays, oracle
            )
            d["pallas_wall_s"] = round(t_wave, 3)
            d["pallas_steps"] = res_pl.n_steps
            if seq_steps:
                limit = min(seq_steps, plan.stats.n_requests)
                seq = wave_exec.run_sequential(
                    plan, arrays, interpret=True, check=False,
                    max_steps=limit,
                )
                d["seq_extrapolated"] = not seq.complete
                d["seq_steps_measured"] = seq.n_steps
                d["seq_measured_wall_s"] = round(seq.elapsed, 3)
                if seq.complete:
                    d["speedup_vs_sequential"] = round(
                        seq.elapsed / max(t_wave, 1e-9), 2
                    )
                else:
                    est = (seq.elapsed / max(seq.n_steps, 1)
                           * plan.stats.n_requests)
                    d["seq_wall_s_extrapolated"] = round(est, 3)
                    d["speedup_vs_sequential_extrapolated"] = round(
                        est / max(t_wave, 1e-9), 2
                    )
        row["depths"][str(depth)] = d
    return row


def smoke():
    """Tier-1 CI gate: all streaming kernels through both engines and
    both backends at depths 1 and 4, everything oracle-asserted."""
    for name in programs.STREAM_KERNELS:
        scale = SMOKE_SCALES[name]
        row = run_kernel(
            name, scale, engines=("cycle", "event"),
            depths=(1, DEFAULT_DEPTH), pallas_depths=(1, DEFAULT_DEPTH),
        )
        bench = programs.get(name)
        prog, arrays, params = bench.make(scale)
        plan = executor.build_wave_plan(prog, _copies(arrays), params)
        seq = wave_exec.run_sequential(plan, arrays, check=True)
        assert seq.complete
        _assert_oracle(
            name, "sequential", seq.arrays, _oracle(name, arrays, params)
        )
        d1 = row["depths"]["1"]
        d4 = row["depths"][str(DEFAULT_DEPTH)]
        assert d1["n_waves"] > d4["n_waves"], (
            f"{name}: deeper queue did not relax the wave partition"
        )
        print(f"{name:12s} smoke OK: cycles={row['engines']['event']['cycles']}"
              f" (cycle==event), waves d1={d1['n_waves']} "
              f"d{DEFAULT_DEPTH}={d4['n_waves']}, "
              f"stalls d1={d1['fifo'][0]['push_stalls']}", flush=True)
    print(f"smoke OK: {len(programs.STREAM_KERNELS)} streaming kernels "
          "through both engines and both wave backends")


def bench(scale_mult: int = 8, seq_steps: int = 256) -> dict:
    out: dict = {"scale_mult": scale_mult, "seq_steps": seq_steps,
                 "fifo_depths": list(DEPTHS), "kernels": {}}
    for name in programs.STREAM_KERNELS:
        scale = programs.get(name).default_scale * scale_mult
        row = run_kernel(name, scale, seq_steps=seq_steps)
        out["kernels"][name] = row
        d = row["depths"]
        waves = {k: v["n_waves"] for k, v in d.items()}
        stalls = {k: v["fifo"][0]["push_stalls"] for k, v in d.items()}
        print(f"{name:12s} @{scale}: "
              f"{d[str(DEFAULT_DEPTH)]['n_requests']} req, waves {waves}, "
              f"push_stalls {stalls}, event cycles "
              f"{row['engines']['event']['cycles']}", flush=True)
    return out


def check_bar(data: dict) -> None:
    for name, row in data["kernels"].items():
        d = row["depths"]
        # deeper queues can only relax slot WAW/WAR chains
        assert (d["1"]["n_waves"] >= d["2"]["n_waves"]
                >= d[str(DEFAULT_DEPTH)]["n_waves"]), (
            f"{name}: wave count not monotone in fifo_depth"
        )
        assert d["1"]["n_waves"] > d[str(DEFAULT_DEPTH)]["n_waves"], (
            f"{name}: fifo_depth axis is flat — depth has no effect"
        )
        par = d[str(DEFAULT_DEPTH)]["parallelism"]
        assert par >= PAR_BAR, (
            f"{name}: wave parallelism {par} below the {PAR_BAR}x bar "
            f"at depth {DEFAULT_DEPTH}"
        )
        for k, v in d.items():
            for qs in v["fifo"]:
                assert qs["pushed"] == qs["popped"] > 0, (
                    f"{name}@depth={k}: unbalanced queue {qs}"
                )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_STREAM.json")
    ap.add_argument("--scale-mult", type=int, default=8)
    ap.add_argument("--seq-steps", type=int, default=256,
                    help="sequential-baseline steps measured before "
                    "extrapolating")
    ap.add_argument("--no-assert", action="store_true")
    ap.add_argument(
        "--smoke", action="store_true",
        help="tier-1 CI gate: reduced scales, both engines and both "
        "backends, oracle-asserted, no JSON",
    )
    a = ap.parse_args()
    if a.smoke:
        smoke()
        return
    data = bench(scale_mult=a.scale_mult, seq_steps=a.seq_steps)
    if not a.no_assert:
        check_bar(data)
    with open(a.out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    waves = {
        k: {d: v["n_waves"] for d, v in row["depths"].items()}
        for k, row in data["kernels"].items()
    }
    print(f"wrote {a.out}: waves by depth {waves}")


if __name__ == "__main__":
    main()
