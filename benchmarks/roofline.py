"""Roofline table generator: reads experiments/dryrun/*.json (written by
launch/dryrun.py) and renders the §Roofline tables for EXPERIMENTS.md."""

from __future__ import annotations

import glob
import json
import os

ARCH_ORDER = [
    "internvl2-76b", "starcoder2-7b", "gemma3-4b", "minicpm3-4b",
    "qwen3-14b", "whisper-tiny", "falcon-mamba-7b",
    "phi3.5-moe-42b-a6.6b", "moonshot-v1-16b-a3b", "zamba2-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(outdir="experiments/dryrun"):
    cells = {}
    for path in glob.glob(os.path.join(outdir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(cells, mesh="16x16"):
    rows = []
    header = (
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL/HLO | HBM GiB/dev | status |"
    )
    rows.append(header)
    rows.append("|" + "---|" * 9)
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, mesh))
            if r is None:
                rows.append(f"| {arch} | {shape} | - | - | - | - | - | - | MISSING |")
                continue
            if "skipped" in r:
                rows.append(
                    f"| {arch} | {shape} | - | - | - | - | - | - | {r['skipped']} |"
                )
                continue
            if "error" in r:
                rows.append(
                    f"| {arch} | {shape} | - | - | - | - | - | - | "
                    f"ERROR {r['error'][:40]} |"
                )
                continue
            rf = r["roofline"]
            mem = r["memory"].get("peak_bytes_per_device_est", 0) / 2**30
            ratio = rf.get("useful_flops_ratio", 0.0)
            rows.append(
                f"| {arch} | {shape} | {fmt_s(rf['compute_s'])} | "
                f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
                f"{rf['dominant']} | {ratio:.2f} | {mem:.1f} | ok |"
            )
    return "\n".join(rows)


def summary(cells):
    ok = sum(1 for r in cells.values() if "roofline" in r)
    skip = sum(1 for r in cells.values() if "skipped" in r)
    err = sum(1 for r in cells.values() if "error" in r)
    return {"ok": ok, "skipped": skip, "errors": err, "total": len(cells)}


def main():
    cells = load()
    print("# 16x16 (single pod, 256 chips)")
    print(table(cells, "16x16"))
    print()
    print("# 2x16x16 (two pods, 512 chips)")
    print(table(cells, "2x16x16"))
    print()
    print("summary:", summary(cells))


if __name__ == "__main__":
    main()
