#!/usr/bin/env python
"""Docs consistency gate (run by CI; see README "Tests").

Checks, failing loudly on the first broken invariant:

  1. every repo-relative path mentioned in README.md / DESIGN.md /
     ROADMAP.md (backtick-quoted or table-cell) exists,
  2. every ``DESIGN.md §N`` cross-reference used anywhere in the
     source tree or docs points at a section heading that exists,
  3. the public API surface the docs and examples lean on has real
     docstrings: every module/function/class named in PUBLIC_API, plus
     every module imported by ``examples/*.py`` from ``repro``,
  4. the CI gate table in README.md and the workflow agree in *both*
     directions: every job in the table exists in
     .github/workflows/ci.yml and every script the table claims a job
     runs is actually invoked there; conversely every workflow job is
     documented in the table and every benchmarks/ or tools/ script the
     workflow invokes is named somewhere in README/DESIGN — so a CI
     refactor cannot silently orphan a documented gate (or document a
     gate that no longer runs),
  5. the README "The knobs" table and ``repro.core.config.RunConfig``
     agree exactly: one table row per dataclass field (backticked field
     name in the first cell), no extra rows, no undocumented fields.

Usage:  python tools/check_docs.py   (repo root, PYTHONPATH-free)
"""

from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
sys.path.insert(0, SRC)

DOCS = ("README.md", "DESIGN.md", "ROADMAP.md")

# (module, attribute or None) — the surface README/DESIGN/examples name
PUBLIC_API = [
    ("repro.core.simulator", "simulate"),
    ("repro.core.simulator", "simulate_traced"),
    ("repro.core.simulator", "Compiled"),
    ("repro.core.simulator", "SimParams"),
    ("repro.core.simulator", "SimResult"),
    ("repro.core.simulator", "SharedArtifacts"),
    ("repro.core.schedule", "compile_pe_trace"),
    ("repro.core.schedule", "trace_program"),
    ("repro.core.monotonic", "analyze_program"),
    ("repro.core.loopir", "interpret"),
    ("repro.core.loopir", "Program"),
    ("repro.core.dae", "decouple"),
    ("repro.core.dae", "record_cu_script"),
    ("repro.core.dae", "ReplayCU"),
    ("repro.core.speculate", "SpecPlan"),
    ("repro.core.speculate", "trace_spec_pe"),
    ("repro.core.du", "check_pair_batch"),
    ("repro.core.config", "RunConfig"),
    ("repro.core.config", "resolve"),
    ("repro.core.executor", "execute"),
    ("repro.core.executor", "build_wave_plan"),
    ("repro.core.executor", "WavePlan"),
    ("repro.core.executor", "validate_plan"),
    ("repro.core.optable", "compile_store_tables"),
    ("repro.core.optable", "StoreTable"),
    ("repro.kernels.wave_exec", "run_plan"),
    ("repro.kernels.wave_exec", "run_sequential"),
    ("repro.core.programs", None),
    ("repro.analysis.deps", "certify_pairs"),
    ("repro.analysis.deps", "stream_facts"),
    ("repro.analysis.deps", "symbolically_free_ops"),
    ("repro.analysis.deps", "check_hint_stream"),
    ("repro.analysis.deps", "HintViolation"),
    ("repro.analysis.lint", "lint_program"),
    ("repro.analysis.lint", "Diagnostic"),
    ("repro.dse", "sweep"),
    ("repro.dse", "SweepSpec"),
    ("repro.dse", "iter_points"),
    ("repro.dse", "sweep_shard"),
    ("repro.dse", "merge_results"),
    ("repro.dse", "shard_plan"),
    ("repro.dse", "calibrate"),
    ("repro.dse.cache", "ResultCache"),
    ("repro.dse.cache", "SweepJournal"),
    ("repro.dse.spec", "result_projection"),
    ("repro.launch.analysis", "sweep_speedups"),
    ("repro.launch.analysis", "pareto_front"),
    ("repro.launch.analysis", "ParetoTracker"),
]

errors: list[str] = []


def err(msg: str) -> None:
    errors.append(msg)
    print(f"FAIL: {msg}")


# -- 1. referenced paths exist ----------------------------------------------
# Docs name files the way the prose reads (`schedule.py`, `core/du.py`,
# `benchmarks/run.py`): a reference resolves if some repo file's path
# ends with it.

_PATH_RE = re.compile(r"`([A-Za-z0-9_./+-]+\.(?:py|md|json|yml|toml))`")

repo_files: set[str] = set()
for dirpath, dirs, files in os.walk(ROOT):
    dirs[:] = [d for d in dirs if d not in (".git", "__pycache__", ".dse_cache")]
    for fn in files:
        repo_files.add(os.path.relpath(os.path.join(dirpath, fn), ROOT))


def path_resolves(rel: str) -> bool:
    return any(f == rel or f.endswith("/" + rel) for f in repo_files)


for doc in DOCS:
    text = open(os.path.join(ROOT, doc)).read()
    for m in _PATH_RE.finditer(text):
        rel = m.group(1)
        if rel.startswith(("/", "~")) or "*" in rel:
            continue
        if not path_resolves(rel):
            err(f"{doc}: referenced path does not exist: {rel}")

# -- 2. DESIGN.md § cross-references resolve --------------------------------

design = open(os.path.join(ROOT, "DESIGN.md")).read()
sections = set()
for line in design.splitlines():
    m = re.match(r"#+\s+§?(\d+)(?:\.(\d+))?[.\s]", line)
    if m:
        sections.add(m.group(1) if m.group(2) is None else f"{m.group(1)}.{m.group(2)}")
ref_re = re.compile(r"DESIGN\.md\s+§(\d+(?:\.\d+)?)")


def scan_refs(path: str, text: str) -> None:
    for m in ref_re.finditer(text):
        sec = m.group(1)
        if sec not in sections and sec.split(".")[0] not in sections:
            err(f"{path}: dangling cross-reference DESIGN.md §{sec}")


for doc in DOCS:
    scan_refs(doc, open(os.path.join(ROOT, doc)).read())
for dirpath, _dirs, files in os.walk(SRC):
    for fn in files:
        if fn.endswith(".py"):
            p = os.path.join(dirpath, fn)
            scan_refs(os.path.relpath(p, ROOT), open(p).read())

# -- 4. CI gates: README table <-> workflow, both directions -----------------
# Parsed with regexes, not pyyaml — CI installs only jax/numpy/pytest/
# hypothesis and this gate must not grow a dependency.

WORKFLOW = os.path.join(ROOT, ".github", "workflows", "ci.yml")

_JOB_RE = re.compile(r"^  ([A-Za-z_][\w-]*):\s*$")
_SCRIPT_RE = re.compile(r"\b((?:benchmarks|tools|examples|tests)/[\w./-]+\.py)\b")


def parse_workflow(path: str) -> tuple[set[str], set[str]]:
    """(job ids, repo-relative scripts invoked by run: commands).

    Comments are stripped before harvesting scripts — a commented-out
    (or merely mentioned) gate must not satisfy the "workflow actually
    invokes it" direction of the check.
    """
    jobs: set[str] = set()
    scripts: set[str] = set()
    in_jobs = False
    for line in open(path):
        if re.match(r"^jobs:\s*$", line):
            in_jobs = True
            continue
        if in_jobs and re.match(r"^[A-Za-z_]", line):
            in_jobs = False  # left the jobs: mapping
        if in_jobs:
            m = _JOB_RE.match(line)
            if m:
                jobs.add(m.group(1))
        scripts.update(_SCRIPT_RE.findall(re.sub(r"#.*", "", line)))
    return jobs, scripts


def parse_gate_table(readme: str) -> list[tuple[str, set[str]]]:
    """Rows of the README "CI gates" table: (job id, scripts named)."""
    rows: list[tuple[str, set[str]]] = []
    in_section = False
    for line in readme.splitlines():
        if re.match(r"^#{2,}\s+CI gates", line):
            in_section = True
            continue
        if in_section and line.startswith("#"):
            break
        if in_section and line.startswith("|"):
            cells = [c.strip() for c in line.strip("|").split("|")]
            if len(cells) < 2 or set(cells[0]) <= {"-", " ", ":"}:
                continue
            job = cells[0].strip("`")
            if job.lower() in ("job", ""):
                continue
            scripts = set()
            for c in cells[1:]:
                scripts.update(_SCRIPT_RE.findall(c))
            rows.append((job, scripts))
    return rows


if not os.path.exists(WORKFLOW):
    err("no CI workflow at .github/workflows/ci.yml")
else:
    wf_jobs, wf_scripts = parse_workflow(WORKFLOW)
    readme_text = open(os.path.join(ROOT, "README.md")).read()
    design_text = open(os.path.join(ROOT, "DESIGN.md")).read()
    gate_rows = parse_gate_table(readme_text)
    if not gate_rows:
        err('README.md: no "CI gates" table (## CI gates section)')
    table_jobs = {job for job, _ in gate_rows}
    for job, scripts in gate_rows:
        if job not in wf_jobs:
            err(f"README CI gates: job '{job}' not in ci.yml "
                f"(workflow has: {sorted(wf_jobs)})")
        for s in scripts:
            if s not in wf_scripts:
                err(f"README CI gates: '{job}' claims `{s}` but the "
                    f"workflow never invokes it")
    for job in sorted(wf_jobs - table_jobs):
        err(f"ci.yml job '{job}' missing from the README CI gates table")
    # every gate script CI runs must be named somewhere in the docs
    doc_text = readme_text + design_text
    for s in sorted(wf_scripts):
        if s.startswith(("benchmarks/", "tools/")) and s not in doc_text:
            err(f"ci.yml invokes `{s}` but neither README.md nor "
                f"DESIGN.md mentions it")

# -- 5. README knobs table <-> RunConfig fields ------------------------------
# One row per dataclass field, backticked field name in the first cell.

import dataclasses


def parse_knob_table(readme: str) -> list[str]:
    """First-cell backticked names of the README "The knobs" table."""
    names: list[str] = []
    in_section = False
    for line in readme.splitlines():
        if re.match(r"^#{2,}\s+The knobs", line):
            in_section = True
            continue
        if in_section and line.startswith("#"):
            break
        if in_section and line.startswith("|"):
            cells = [c.strip() for c in line.strip("|").split("|")]
            if len(cells) < 2 or set(cells[0]) <= {"-", " ", ":"}:
                continue
            m = re.match(r"^`([A-Za-z_]+)`", cells[0])
            if m:
                names.append(m.group(1))
    return names


try:
    from repro.core.config import RunConfig as _RunConfig
except Exception as e:
    err(f"cannot import repro.core.config.RunConfig: {e}")
else:
    knob_rows = parse_knob_table(open(os.path.join(ROOT, "README.md")).read())
    cfg_fields = [f.name for f in dataclasses.fields(_RunConfig)]
    if not knob_rows:
        err('README.md: no "The knobs" table (## The knobs section)')
    for name in sorted(set(cfg_fields) - set(knob_rows)):
        err(f"README knobs table: RunConfig field `{name}` has no row")
    for name in sorted(set(knob_rows) - set(cfg_fields)):
        err(f"README knobs table: row `{name}` is not a RunConfig field")
    dupes = {n for n in knob_rows if knob_rows.count(n) > 1}
    for name in sorted(dupes):
        err(f"README knobs table: duplicate row `{name}`")

# -- 3. docstring audit ------------------------------------------------------

import importlib


def check_docstring(modname: str, attr):
    try:
        mod = importlib.import_module(modname)
    except Exception as e:  # jax etc. must be importable in CI
        err(f"cannot import {modname}: {e}")
        return
    if not (mod.__doc__ or "").strip():
        err(f"{modname}: module has no docstring")
    if attr is not None:
        obj = getattr(mod, attr, None)
        if obj is None:
            err(f"{modname}.{attr}: does not exist")
        elif not (getattr(obj, "__doc__", "") or "").strip():
            err(f"{modname}.{attr}: no docstring")


for modname, attr in PUBLIC_API:
    check_docstring(modname, attr)

# every repro module an example imports must have a module docstring
ex_dir = os.path.join(ROOT, "examples")
imported: set[str] = set()
for fn in sorted(os.listdir(ex_dir)):
    if not fn.endswith(".py"):
        continue
    tree = ast.parse(open(os.path.join(ex_dir, fn)).read())
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            imported.update(
                a.name for a in node.names if a.name.startswith("repro")
            )
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module.startswith("repro"):
                imported.add(node.module)
for modname in sorted(imported):
    check_docstring(modname, None)

if errors:
    print(f"\n{len(errors)} docs problem(s)")
    sys.exit(1)
print("docs OK: paths resolve, §-references valid, public API documented "
      f"({len(PUBLIC_API)} symbols + {len(imported)} example imports)")
